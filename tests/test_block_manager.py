"""Paged KV-cache tests: block allocator, refcount/copy-on-write sharing,
FP8/BF16 capacity ratio, paged attention numerics + kernel, and
engine-level preemption/swap/prefix-sharing invariants (ports the spirit
of vLLM's test_device_aware_block_allocator.py and
test_prefix_caching_block.py)."""
from collections import Counter

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT, FULL_FP8_ROLLOUT
from repro.core import quant as cq
from repro.data import tasks
from repro.models import decode_step, init_cache, init_params, prefill
from repro.rl import sync_policy_weights
from repro.serving import (
    BlockManager,
    NoFreeBlocksError,
    Request,
    ServingEngine,
    kv_bytes_per_token,
)

jax.config.update("jax_platform_name", "cpu")


def _cfg():
    return get_config("qwen3-8b").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab_size=tasks.VOCAB_SIZE,
        n_heads=4, n_kv_heads=2, d_head=16)


def _prompts(n, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    out = []
    for _ in range(n):
        p = rng.integers(4, 19, size=int(rng.integers(4, 9)))
        out.append(np.concatenate([[tasks.BOS], p]).astype(np.int32))
    return out


# ---------------------------------------------------------------------------
# BlockManager: allocation / free / OOM
# ---------------------------------------------------------------------------

def test_allocate_free_roundtrip():
    mgr = BlockManager(num_blocks=8, block_size=4, bytes_per_token=16)
    assert mgr.num_free_blocks == 8 and mgr.blocks_in_use == 0
    a = mgr.allocate(rid=1, n_blocks=3)
    b = mgr.allocate(rid=2, n_blocks=5)
    assert len(a) == 3 and len(b) == 5
    assert not set(a) & set(b)                 # no double allocation
    assert mgr.num_free_blocks == 0
    assert mgr.bytes_in_use == 8 * 4 * 16
    mgr.free(1)
    assert mgr.num_free_blocks == 3
    assert sorted(mgr.blocks_of(2)) == sorted(b)   # rid 2 untouched
    mgr.free(2)
    assert mgr.num_free_blocks == 8 and mgr.blocks_in_use == 0


def test_allocate_oom_raises_and_state_intact():
    mgr = BlockManager(num_blocks=4, block_size=2)
    mgr.allocate(rid=0, n_blocks=3)
    with pytest.raises(NoFreeBlocksError):
        mgr.allocate(rid=1, n_blocks=2)
    assert mgr.num_free_blocks == 1            # failed alloc took nothing
    assert mgr.blocks_of(1) == []
    assert not mgr.can_allocate(2)
    assert mgr.can_allocate(1)
    assert not mgr.can_allocate(1, limit_blocks=3)   # soft limit binds


def test_ensure_capacity_grows_by_ceil():
    mgr = BlockManager(num_blocks=10, block_size=4)
    assert len(mgr.ensure_capacity(rid=7, n_tokens=5)) == 2   # ceil(5/4)
    assert mgr.ensure_capacity(rid=7, n_tokens=8) == []       # already fits
    assert len(mgr.ensure_capacity(rid=7, n_tokens=9)) == 1
    assert mgr.blocks_for_tokens(0) == 0
    assert mgr.blocks_for_tokens(1) == 1


def test_allocate_enforces_limit_blocks_like_can_allocate():
    """`allocate` must reject exactly what `can_allocate` rejects — the two
    disagreeing under on-demand admission was a real bug (allocate used to
    ignore the soft cap entirely)."""
    mgr = BlockManager(num_blocks=8, block_size=4)
    mgr.allocate(rid=0, n_blocks=2)
    assert not mgr.can_allocate(2, limit_blocks=3)
    with pytest.raises(NoFreeBlocksError):
        mgr.allocate(rid=1, n_blocks=2, limit_blocks=3)
    assert mgr.blocks_of(1) == [] and mgr.num_free_blocks == 6  # intact
    assert mgr.can_allocate(1, limit_blocks=3)
    mgr.allocate(rid=1, n_blocks=1, limit_blocks=3)
    assert mgr.blocks_in_use == 3


# ---------------------------------------------------------------------------
# refcounts / prefix index / copy-on-write
# ---------------------------------------------------------------------------

def test_shared_block_double_free_impossible():
    mgr = BlockManager(num_blocks=4, block_size=4)
    a = mgr.allocate(rid=0, n_blocks=2)
    mgr.acquire(1, a)                       # rid 1 shares both blocks
    assert all(mgr.refcount(b) == 2 for b in a)
    assert mgr.free(0) == []                # still referenced: nothing freed
    assert mgr.num_free_blocks == 2 and all(mgr.refcount(b) == 1 for b in a)
    assert mgr.free(0) == []                # double free: no-op by design
    assert sorted(mgr.free(1)) == sorted(a)  # last holder frees for real
    assert mgr.free(1) == []
    assert mgr.num_free_blocks == 4 and mgr.blocks_in_use == 0


def test_prefix_index_lookup_and_lifetime():
    mgr = BlockManager(num_blocks=8, block_size=4)
    prompt = np.arange(10, 20, dtype=np.int32)      # 2 full blocks + 2 toks
    ids = mgr.allocate(rid=0, n_blocks=3)
    assert mgr.register_prefix(0, prompt) == 2      # partial block not indexed
    assert mgr.lookup_prefix(prompt) == ids[:2]
    assert mgr.lookup_prefix(prompt[:8]) == ids[:2]
    assert mgr.lookup_prefix(prompt[:7]) == ids[:1]  # only 1 full block
    div = prompt.copy()
    div[5] = 99                                      # diverges in block 2
    assert mgr.lookup_prefix(div) == ids[:1]
    assert mgr.lookup_prefix(div[::-1]) == []
    # refcount 0 moves indexed blocks to the evictor cache: entries SURVIVE
    # (vLLM evictor) and a same-prompt lookup can revive them for free
    mgr.free(0)
    assert mgr.num_cached_blocks == 2 and mgr.blocks_in_use == 0
    assert mgr.lookup_prefix(prompt) == ids[:2]
    mgr.acquire(1, mgr.lookup_prefix(prompt))        # revival: cache -> live
    assert mgr.num_cached_blocks == 0
    assert all(mgr.refcount(b) == 1 for b in ids[:2])
    mgr.free(1)
    # entries die only when the space is actually needed: exhaust the pool
    mgr.allocate(rid=2, n_blocks=8)
    assert mgr.num_cached_blocks == 0
    assert mgr.lookup_prefix(prompt) == []
    off = BlockManager(num_blocks=8, block_size=4, enable_prefix_sharing=False)
    off.allocate(rid=0, n_blocks=3)
    assert off.register_prefix(0, prompt) == 0
    assert off.lookup_prefix(prompt) == []


def test_fork_and_cow_semantics():
    mgr = BlockManager(num_blocks=4, block_size=4)
    a = mgr.allocate(rid=0, n_blocks=2)
    assert mgr.fork(0, 1) == a                   # dst shares the whole table
    assert all(mgr.refcount(b) == 2 for b in a)
    with pytest.raises(NoFreeBlocksError):
        mgr.cow(1, 1, limit_blocks=mgr.blocks_in_use)  # same cap as allocate
    assert mgr.blocks_of(1) == a                 # failed cow changed nothing
    old, new = mgr.cow(1, 1, limit_blocks=mgr.blocks_in_use + 1)
    assert old == a[1] and new not in a
    assert mgr.blocks_of(1) == [a[0], new]
    assert mgr.blocks_of(0) == a                 # donor table untouched
    assert mgr.refcount(old) == 1 and mgr.refcount(new) == 1
    assert mgr.cow(1, 1) is None                 # now exclusive: no copy
    # exhaust the pool: cow must fail loudly, not corrupt
    mgr.allocate(rid=2, n_blocks=mgr.num_free_blocks)
    with pytest.raises(NoFreeBlocksError):
        mgr.cow(1, 0)
    assert mgr.blocks_of(1) == [a[0], new]


def test_pool_accounting_under_interleaved_share_fork_free():
    mgr = BlockManager(num_blocks=8, block_size=2, bytes_per_token=16)
    a = mgr.allocate(rid=0, n_blocks=3)
    mgr.acquire(1, a[:2])
    mgr.allocate(rid=1, n_blocks=1)
    assert mgr.blocks_in_use == 4                # sharing costs no blocks
    assert mgr.bytes_in_use == 4 * 2 * 16
    mgr.fork(0, 2)
    assert mgr.blocks_in_use == 4
    mgr.cow(2, 2)                                # privatize one entry
    assert mgr.blocks_in_use == 5
    mgr.free(0)
    assert mgr.blocks_in_use == 4                # only a[2] died with rid 0
    mgr.free(1)
    assert mgr.blocks_in_use == 3                # rid 1's private block dies
    mgr.free(2)
    assert mgr.blocks_in_use == 0 and mgr.bytes_in_use == 0
    assert mgr.num_free_blocks == 8


def test_refcount_property_random_share_free_sequences():
    hyp = pytest.importorskip("hypothesis")
    st = hyp.strategies

    @hyp.settings(deadline=None, max_examples=60)
    @hyp.given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 15)),
                        max_size=40))
    def run(ops):
        mgr = BlockManager(num_blocks=8, block_size=4)
        for op, arg in ops:
            rid = arg % 4
            if op == 0 and mgr.can_allocate(1):
                mgr.allocate(rid, 1)
            elif op == 1:
                src = (arg // 4) % 4
                if src != rid and mgr.blocks_of(src):
                    mgr.acquire(rid, mgr.blocks_of(src)[:1])
            elif op == 2:
                mgr.free(rid)
            elif op == 3:
                for i, b in enumerate(mgr.blocks_of(rid)):
                    if mgr.is_shared(b) and mgr.num_free_blocks:
                        mgr.cow(rid, i)
                        break
            # the invariants: refcounts == ownership multiplicity, the free
            # list is disjoint from live blocks, nothing leaks or double-
            # allocates
            live = Counter(b for ids in mgr._owned.values() for b in ids)
            assert dict(live) == mgr._refcount
            assert set(mgr._free).isdisjoint(live)
            assert len(mgr._free) + len(live) == 8
            assert mgr.blocks_in_use == len(live)
        for rid in range(4):
            mgr.free(rid)
        assert mgr.num_free_blocks == 8 and not mgr._refcount

    run()


# ---------------------------------------------------------------------------
# byte accounting: FP8 blocks hold exactly 2x the tokens of BF16 blocks
# ---------------------------------------------------------------------------

def test_fp8_blocks_hold_2x_tokens_at_equal_byte_size():
    cfg = _cfg()
    per_b16 = kv_bytes_per_token(cfg, BF16_ROLLOUT)
    per_fp8 = kv_bytes_per_token(cfg, FP8_KV_ONLY_ROLLOUT)
    assert per_b16 == 2 * per_fp8 > 0
    budget, block_bytes = per_b16 * 64, per_b16 * 8
    m16 = BlockManager.from_byte_budget(budget, block_bytes, per_b16)
    m8 = BlockManager.from_byte_budget(budget, block_bytes, per_fp8)
    assert m16.num_blocks == m8.num_blocks          # same pool, same bytes
    assert m8.block_size == 2 * m16.block_size      # 2x tokens per block
    assert m8.capacity_tokens == 2 * m16.capacity_tokens


# ---------------------------------------------------------------------------
# paged cache numerics: block-table gather == contiguous cache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", [BF16_ROLLOUT, FULL_FP8_ROLLOUT],
                         ids=["bf16", "fp8"])
def test_paged_prefill_decode_matches_contiguous(precision):
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    roll, _ = sync_policy_weights(params, precision)
    prompts = jnp.array([[1, 5, 6, 7, 8, 0], [1, 9, 10, 11, 0, 0]], jnp.int32)
    lens = jnp.array([5, 4])
    seqs = {}
    for mode, kw in (("contig", {}), ("paged", dict(page_size=4))):
        cache = init_cache(cfg, 2, 16, precision, dtype=jnp.float32, **kw)
        lg, cache = prefill(roll, {"tokens": prompts, "lengths": lens},
                            cache, cfg, precision)
        seq, tok = [np.asarray(lg)], jnp.argmax(lg, -1)
        for _ in range(3):
            lg, cache, _ = decode_step(roll, tok, cache, cfg, precision)
            seq.append(np.asarray(lg))
            tok = jnp.argmax(lg, -1)
        seqs[mode] = seq
    for a, b in zip(seqs["contig"], seqs["paged"]):
        np.testing.assert_array_equal(a, b)


def test_paged_kernel_matches_ref():
    from repro.kernels import fp8_kv_attention as attn_mod
    from repro.kernels import ref
    ks = jax.random.split(jax.random.key(3), 3)
    b, kvh, g, d, n, bs = 2, 2, 4, 64, 9, 16
    q = jax.random.normal(ks[0], (b, kvh, g, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (n, bs, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (n, bs, kvh, d), jnp.float32)
    k_s = jnp.float32(jnp.abs(k).max() / 448.0)
    v_s = jnp.float32(jnp.abs(v).max() / 448.0)
    kq = cq.quantize_per_tensor(k, k_s, jnp.float8_e4m3fn)
    vq = cq.quantize_per_tensor(v, v_s, jnp.float8_e4m3fn)
    # row 8 doubles as the trash block for unmapped tail entries
    tbl = jnp.array([[3, 0, 7, 8], [5, 2, 8, 8]], jnp.int32)
    lengths = jnp.array([37, 20], jnp.int32)
    out_k = attn_mod.fp8_paged_decode_attention(
        q, kq, vq, k_s, v_s, tbl, lengths, interpret=True)
    out_r = ref.fp8_paged_decode_attention_ref(
        q, kq, vq, k_s, v_s, tbl, lengths)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# engine-level: preemption frees blocks, swap resumes without recompute
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _run_engine(cfg, roll, prec, budget_tokens_bf16, prompts, *,
                admission="ondemand", max_new=8, max_slots=4):
    per_b16 = kv_bytes_per_token(cfg, BF16_ROLLOUT)
    eng = ServingEngine(roll, cfg, prec, max_slots=max_slots, max_seq_len=32,
                        kv_budget_bytes=per_b16 * budget_tokens_bf16,
                        admission=admission)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new=max_new, rid=i)
    return eng, eng.run(max_steps=500)


def test_preemption_frees_blocks_and_swap_resumes(setup):
    """On-demand admission over-commits a tight pool: preemption must free
    the victim's blocks (pool never leaks) and the victim must finish with
    the exact tokens of an uncontended run — i.e. swapped blocks are
    restored, not recomputed."""
    cfg, params = setup
    prompts = _prompts(6)
    # uncontended reference: big budget, no preemption possible
    eng_ref, rep_ref = _run_engine(cfg, params, BF16_ROLLOUT, 400, prompts)
    assert rep_ref.preemptions == 0
    ref_out = {r.rid: list(r.generated) for r in rep_ref.completed}

    eng, rep = _run_engine(cfg, params, BF16_ROLLOUT, 40, prompts)
    assert rep.preemptions >= 1 and rep.swap_outs >= 1 and rep.swap_ins >= 1
    assert len(rep.completed) == 6
    # pool fully drained at the end: preemption/completion freed every block
    assert eng.block_mgr.blocks_in_use == 0
    assert eng.block_mgr.num_free_blocks == eng.block_mgr.num_blocks
    # greedy decode is deterministic: swap-resume must continue bit-exact,
    # so every request's tokens match the uncontended run
    got_out = {r.rid: list(r.generated) for r in rep.completed}
    assert got_out == ref_out
    # the preemption tax is visible: every token a victim had to restore
    # from host on swap-in is counted (and only those — no recompute)
    assert rep.swap_ins >= 1 and rep.wasted_tokens >= 1
    assert rep.wasted_tokens == sum(r.wasted_tokens for r in rep.completed)
    assert rep_ref.wasted_tokens == 0          # no preemption, no tax


def test_fp8_kv_removes_preemptions_at_fixed_budget(setup):
    """At a byte budget where BF16 KV preempts, FP8 KV serves the identical
    workload preemption-free with a higher useful token rate (§2.3.2)."""
    cfg, params = setup
    prompts = _prompts(6)
    reports = {}
    for name, prec in (("bf16", BF16_ROLLOUT), ("fp8", FP8_KV_ONLY_ROLLOUT)):
        roll, _ = sync_policy_weights(params, prec)
        _, reports[name] = _run_engine(cfg, roll, prec, 48, prompts)
    assert reports["bf16"].preemptions >= 1
    assert reports["fp8"].preemptions == 0
    assert len(reports["fp8"].completed) == 6
    assert len(reports["bf16"].completed) == 6
    assert reports["fp8"].useful_token_rate > reports["bf16"].useful_token_rate
    assert reports["fp8"].budget_tokens == 2 * reports["bf16"].budget_tokens


# ---------------------------------------------------------------------------
# engine-level prefix sharing: dedup'd admission, CoW, preemption safety
# ---------------------------------------------------------------------------

def test_same_prompt_group_admits_with_shared_prompt_blocks(setup):
    """N same-prompt requests (the GRPO shape) must admit with
    prompt_blocks + N*decode_blocks, not N*(prompt + decode): every
    request past the first dedups its full prompt blocks against the
    prefix index."""
    cfg, params = setup
    n = 4
    prompt = np.concatenate([[tasks.BOS], np.arange(5, 12)]).astype(np.int32)
    assert len(prompt) == 8                       # 2 full bf16 blocks of 4
    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=n,
                        max_seq_len=32, admission="reserve")
    for i in range(n):
        eng.submit(prompt, max_new=8, rid=i)
    eng._try_admit()
    mgr = eng.block_mgr
    prompt_blocks = mgr.blocks_for_tokens(len(prompt))          # 2
    total_blocks = mgr.blocks_for_tokens(len(prompt) + 8)       # 4
    decode_blocks = total_blocks - prompt_blocks                # 2
    assert mgr.blocks_in_use == prompt_blocks + n * decode_blocks
    assert eng.stats["prefix_hits"] == (n - 1) * prompt_blocks
    # every active table starts with the same two physical blocks
    tables = [mgr.blocks_of(i) for i in range(n)]
    assert all(t[:prompt_blocks] == tables[0][:prompt_blocks] for t in tables)
    assert all(mgr.refcount(b) == n for b in tables[0][:prompt_blocks])
    # and the workload completes bit-identically to a sharing-off engine
    rep = eng.run(max_steps=100)
    eng_off = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=n,
                            max_seq_len=32, admission="reserve",
                            prefix_sharing=False)
    for i in range(n):
        eng_off.submit(prompt, max_new=8, rid=i)
    rep_off = eng_off.run(max_steps=100)
    assert {r.rid: r.generated for r in rep.completed} == \
        {r.rid: r.generated for r in rep_off.completed}
    assert rep.peak_blocks_in_use < rep_off.peak_blocks_in_use
    assert mgr.blocks_in_use == 0                 # refcounts fully drained


def test_cow_guard_on_forked_partial_block(setup):
    """Fork a mid-flight request's table (rollout-style: shared partial
    boundary block), then decode both: the first divergent append must
    copy-on-write, and the donor's tokens must stay bit-exact vs an
    uncontended run."""
    cfg, params = setup
    prompt = np.array([tasks.BOS, 5, 6, 7, 8, 9], np.int32)  # block 1 partial
    ref = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=32)
    ref.submit(prompt, max_new=6, rid=0)
    ref_tokens = ref.run(max_steps=50).completed[0].generated

    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=32)
    eng.submit(prompt, max_new=6, rid=0)
    eng._try_admit()                              # rid 0 live in slot 0
    req_b = Request(rid=1, prompt=prompt, max_new=6,
                    prefilled=len(prompt), cached_tokens=len(prompt))
    eng.block_mgr.fork(0, 1)                      # share ALL blocks
    slot = eng._free_slot()
    eng._set_table_row(slot, eng.block_mgr.blocks_of(1))
    eng.cache["lengths"] = eng.cache["lengths"].at[slot].set(len(prompt))
    eng.pending_tok[slot] = eng.pending_tok[0]
    req_b.generated = [int(eng.pending_tok[0])]
    eng.slot_req[slot] = req_b
    rep = eng.run(max_steps=50)
    assert rep.cow_copies >= 1                    # the guard actually fired
    got = {r.rid: list(r.generated) for r in rep.completed}
    assert got[0] == ref_tokens                   # donor bit-exact
    assert got[1] == ref_tokens                   # same prompt+seed token
    assert eng.block_mgr.blocks_in_use == 0


def test_preemption_never_evicts_shared_blocks(setup):
    """Under a budget tight enough to preempt, a victim's blocks that are
    still referenced by an active request must stay resident (refcount
    >= 1, not on the free list), and everyone must still finish with the
    uncontended tokens."""
    cfg, params = setup
    n = 6
    prompt = np.concatenate([[tasks.BOS], np.arange(5, 12)]).astype(np.int32)
    per_b16 = kv_bytes_per_token(cfg, BF16_ROLLOUT)

    def build(budget_tokens):
        eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=4,
                            max_seq_len=32, admission="ondemand",
                            kv_budget_bytes=per_b16 * budget_tokens)
        for i in range(n):
            eng.submit(prompt, max_new=8, rid=i)
        return eng

    ref_out = {r.rid: list(r.generated)
               for r in build(400).run(max_steps=400).completed}

    eng = build(32)                               # tight: forces preemption
    shared_seen = []
    sched = eng.scheduler
    orig_plan_swap_out = sched._plan_swap_out

    def checked_plan_swap_out(e, decision, slot, planned, *args, **kw):
        req = e.slot_req[slot]
        shared = [b for b in e.block_mgr.blocks_of(req.rid)
                  if e.block_mgr.is_shared(b)]
        orig_plan_swap_out(e, decision, slot, planned, *args, **kw)
        for b in shared:                          # still held by someone else
            assert e.block_mgr.refcount(b) >= 1
            assert b not in e.block_mgr._free
        shared_seen.extend(shared)

    sched._plan_swap_out = checked_plan_swap_out
    rep = eng.run(max_steps=400)
    assert rep.preemptions >= 1 and shared_seen   # the invariant was tested
    assert len(rep.completed) == n
    assert {r.rid: list(r.generated) for r in rep.completed} == ref_out
    assert eng.block_mgr.blocks_in_use == 0
