"""Paged KV-cache tests: block allocator, FP8/BF16 capacity ratio, paged
attention numerics + kernel, and engine-level preemption/swap invariants
(ports the spirit of vLLM's test_device_aware_block_allocator.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT, FULL_FP8_ROLLOUT
from repro.core import quant as cq
from repro.data import tasks
from repro.models import decode_step, init_cache, init_params, prefill
from repro.rl import sync_policy_weights
from repro.serving import (
    BlockManager,
    NoFreeBlocksError,
    ServingEngine,
    kv_bytes_per_token,
)

jax.config.update("jax_platform_name", "cpu")


def _cfg():
    return get_config("qwen3-8b").reduced(
        n_layers=2, d_model=64, d_ff=128, vocab_size=tasks.VOCAB_SIZE,
        n_heads=4, n_kv_heads=2, d_head=16)


def _prompts(n, rng_seed=0):
    rng = np.random.default_rng(rng_seed)
    out = []
    for _ in range(n):
        p = rng.integers(4, 19, size=int(rng.integers(4, 9)))
        out.append(np.concatenate([[tasks.BOS], p]).astype(np.int32))
    return out


# ---------------------------------------------------------------------------
# BlockManager: allocation / free / OOM
# ---------------------------------------------------------------------------

def test_allocate_free_roundtrip():
    mgr = BlockManager(num_blocks=8, block_size=4, bytes_per_token=16)
    assert mgr.num_free_blocks == 8 and mgr.blocks_in_use == 0
    a = mgr.allocate(rid=1, n_blocks=3)
    b = mgr.allocate(rid=2, n_blocks=5)
    assert len(a) == 3 and len(b) == 5
    assert not set(a) & set(b)                 # no double allocation
    assert mgr.num_free_blocks == 0
    assert mgr.bytes_in_use == 8 * 4 * 16
    mgr.free(1)
    assert mgr.num_free_blocks == 3
    assert sorted(mgr.blocks_of(2)) == sorted(b)   # rid 2 untouched
    mgr.free(2)
    assert mgr.num_free_blocks == 8 and mgr.blocks_in_use == 0


def test_allocate_oom_raises_and_state_intact():
    mgr = BlockManager(num_blocks=4, block_size=2)
    mgr.allocate(rid=0, n_blocks=3)
    with pytest.raises(NoFreeBlocksError):
        mgr.allocate(rid=1, n_blocks=2)
    assert mgr.num_free_blocks == 1            # failed alloc took nothing
    assert mgr.blocks_of(1) == []
    assert not mgr.can_allocate(2)
    assert mgr.can_allocate(1)
    assert not mgr.can_allocate(1, limit_blocks=3)   # soft limit binds


def test_ensure_capacity_grows_by_ceil():
    mgr = BlockManager(num_blocks=10, block_size=4)
    assert len(mgr.ensure_capacity(rid=7, n_tokens=5)) == 2   # ceil(5/4)
    assert mgr.ensure_capacity(rid=7, n_tokens=8) == []       # already fits
    assert len(mgr.ensure_capacity(rid=7, n_tokens=9)) == 1
    assert mgr.blocks_for_tokens(0) == 0
    assert mgr.blocks_for_tokens(1) == 1


# ---------------------------------------------------------------------------
# byte accounting: FP8 blocks hold exactly 2x the tokens of BF16 blocks
# ---------------------------------------------------------------------------

def test_fp8_blocks_hold_2x_tokens_at_equal_byte_size():
    cfg = _cfg()
    per_b16 = kv_bytes_per_token(cfg, BF16_ROLLOUT)
    per_fp8 = kv_bytes_per_token(cfg, FP8_KV_ONLY_ROLLOUT)
    assert per_b16 == 2 * per_fp8 > 0
    budget, block_bytes = per_b16 * 64, per_b16 * 8
    m16 = BlockManager.from_byte_budget(budget, block_bytes, per_b16)
    m8 = BlockManager.from_byte_budget(budget, block_bytes, per_fp8)
    assert m16.num_blocks == m8.num_blocks          # same pool, same bytes
    assert m8.block_size == 2 * m16.block_size      # 2x tokens per block
    assert m8.capacity_tokens == 2 * m16.capacity_tokens


# ---------------------------------------------------------------------------
# paged cache numerics: block-table gather == contiguous cache
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", [BF16_ROLLOUT, FULL_FP8_ROLLOUT],
                         ids=["bf16", "fp8"])
def test_paged_prefill_decode_matches_contiguous(precision):
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0), dtype=jnp.float32)
    roll, _ = sync_policy_weights(params, precision)
    prompts = jnp.array([[1, 5, 6, 7, 8, 0], [1, 9, 10, 11, 0, 0]], jnp.int32)
    lens = jnp.array([5, 4])
    seqs = {}
    for mode, kw in (("contig", {}), ("paged", dict(page_size=4))):
        cache = init_cache(cfg, 2, 16, precision, dtype=jnp.float32, **kw)
        lg, cache = prefill(roll, {"tokens": prompts, "lengths": lens},
                            cache, cfg, precision)
        seq, tok = [np.asarray(lg)], jnp.argmax(lg, -1)
        for _ in range(3):
            lg, cache, _ = decode_step(roll, tok, cache, cfg, precision)
            seq.append(np.asarray(lg))
            tok = jnp.argmax(lg, -1)
        seqs[mode] = seq
    for a, b in zip(seqs["contig"], seqs["paged"]):
        np.testing.assert_array_equal(a, b)


def test_paged_kernel_matches_ref():
    from repro.kernels import fp8_kv_attention as attn_mod
    from repro.kernels import ref
    ks = jax.random.split(jax.random.key(3), 3)
    b, kvh, g, d, n, bs = 2, 2, 4, 64, 9, 16
    q = jax.random.normal(ks[0], (b, kvh, g, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (n, bs, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (n, bs, kvh, d), jnp.float32)
    k_s = jnp.float32(jnp.abs(k).max() / 448.0)
    v_s = jnp.float32(jnp.abs(v).max() / 448.0)
    kq = cq.quantize_per_tensor(k, k_s, jnp.float8_e4m3fn)
    vq = cq.quantize_per_tensor(v, v_s, jnp.float8_e4m3fn)
    # row 8 doubles as the trash block for unmapped tail entries
    tbl = jnp.array([[3, 0, 7, 8], [5, 2, 8, 8]], jnp.int32)
    lengths = jnp.array([37, 20], jnp.int32)
    out_k = attn_mod.fp8_paged_decode_attention(
        q, kq, vq, k_s, v_s, tbl, lengths, interpret=True)
    out_r = ref.fp8_paged_decode_attention_ref(
        q, kq, vq, k_s, v_s, tbl, lengths)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# engine-level: preemption frees blocks, swap resumes without recompute
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _run_engine(cfg, roll, prec, budget_tokens_bf16, prompts, *,
                admission="ondemand", max_new=8, max_slots=4):
    per_b16 = kv_bytes_per_token(cfg, BF16_ROLLOUT)
    eng = ServingEngine(roll, cfg, prec, max_slots=max_slots, max_seq_len=32,
                        kv_budget_bytes=per_b16 * budget_tokens_bf16,
                        admission=admission)
    for i, p in enumerate(prompts):
        eng.submit(p, max_new=max_new, rid=i)
    return eng, eng.run(max_steps=500)


def test_preemption_frees_blocks_and_swap_resumes(setup):
    """On-demand admission over-commits a tight pool: preemption must free
    the victim's blocks (pool never leaks) and the victim must finish with
    the exact tokens of an uncontended run — i.e. swapped blocks are
    restored, not recomputed."""
    cfg, params = setup
    prompts = _prompts(6)
    # uncontended reference: big budget, no preemption possible
    eng_ref, rep_ref = _run_engine(cfg, params, BF16_ROLLOUT, 400, prompts)
    assert rep_ref.preemptions == 0
    ref_out = {r.rid: list(r.generated) for r in rep_ref.completed}

    eng, rep = _run_engine(cfg, params, BF16_ROLLOUT, 40, prompts)
    assert rep.preemptions >= 1 and rep.swap_outs >= 1 and rep.swap_ins >= 1
    assert len(rep.completed) == 6
    # pool fully drained at the end: preemption/completion freed every block
    assert eng.block_mgr.blocks_in_use == 0
    assert eng.block_mgr.num_free_blocks == eng.block_mgr.num_blocks
    # greedy decode is deterministic: swap-resume must continue bit-exact,
    # so every request's tokens match the uncontended run
    got_out = {r.rid: list(r.generated) for r in rep.completed}
    assert got_out == ref_out
    # swap path means retained work is never recomputed -> nothing wasted
    assert rep.wasted_tokens == 0


def test_fp8_kv_removes_preemptions_at_fixed_budget(setup):
    """At a byte budget where BF16 KV preempts, FP8 KV serves the identical
    workload preemption-free with a higher useful token rate (§2.3.2)."""
    cfg, params = setup
    prompts = _prompts(6)
    reports = {}
    for name, prec in (("bf16", BF16_ROLLOUT), ("fp8", FP8_KV_ONLY_ROLLOUT)):
        roll, _ = sync_policy_weights(params, prec)
        _, reports[name] = _run_engine(cfg, roll, prec, 48, prompts)
    assert reports["bf16"].preemptions >= 1
    assert reports["fp8"].preemptions == 0
    assert len(reports["fp8"].completed) == 6
    assert len(reports["bf16"].completed) == 6
    assert reports["fp8"].useful_token_rate > reports["bf16"].useful_token_rate
    assert reports["fp8"].budget_tokens == 2 * reports["bf16"].budget_tokens
