"""Rollout-side prefix sharing: GRPO group sampling forks per-sample block
tables off one shared prefill (rl/rollout.py `num_samples_per_prompt`).

The load-bearing claim is bit-exactness: sharing the prompt's physical KV
blocks and copy-on-writing the boundary block must be invisible to the
model — a group run must produce byte-identical trajectories to the naive
path that prefills every sample separately on identity tables."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import BF16_ROLLOUT, FULL_FP8_ROLLOUT
from repro.data import tasks
from repro.models import init_params
from repro.rl import sync_policy_weights
from repro.rl.rollout import SamplerConfig, generate

jax.config.update("jax_platform_name", "cpu")


def _cfg(name="qwen3-8b", **kw):
    base = dict(n_layers=2, d_model=64, d_ff=128,
                vocab_size=tasks.VOCAB_SIZE, n_heads=4, n_kv_heads=2,
                d_head=16)
    base.update(kw)
    return get_config(name).reduced(**base)


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


@pytest.mark.parametrize("precision", [BF16_ROLLOUT, FULL_FP8_ROLLOUT],
                         ids=["bf16", "fp8"])
def test_group_sampling_matches_identity_tables(setup, precision):
    """Same key, temperature 1: the forked-table group run must equal the
    naive tiled run token-for-token and logprob-for-logprob.  Divergent
    appends land in the shared boundary block's CoW copies, so any
    cross-sample corruption would break this equality."""
    cfg, params = setup
    roll, _ = sync_policy_weights(params, precision)
    prompts = jnp.array([[1, 5, 6, 7, 8, 9],
                         [1, 9, 10, 11, 12, 4]], jnp.int32)
    plens = jnp.array([6, 6])
    samp = SamplerConfig(max_new_tokens=6, temperature=1.0)
    group = 3
    t_g = generate(roll, prompts, plens, jax.random.key(7), cfg, precision,
                   samp, page_size=4, num_samples_per_prompt=group,
                   shared_prefix_blocks=1)
    t_ref = generate(roll, jnp.repeat(prompts, group, 0),
                     jnp.repeat(plens, group, 0), jax.random.key(7), cfg,
                     precision, samp, page_size=4)
    assert t_g.response_tokens.shape == (2 * group, 6)
    np.testing.assert_array_equal(np.asarray(t_g.response_tokens),
                                  np.asarray(t_ref.response_tokens))
    np.testing.assert_array_equal(np.asarray(t_g.rollout_logps),
                                  np.asarray(t_ref.rollout_logps))
    np.testing.assert_array_equal(np.asarray(t_g.response_mask),
                                  np.asarray(t_ref.response_mask))
    np.testing.assert_array_equal(np.asarray(t_g.prompt_tokens),
                                  np.asarray(t_ref.prompt_tokens))
    # samples within a group genuinely diverged (CoW was exercised, not
    # bypassed by everyone sampling the same continuation)
    resp = np.asarray(t_g.response_tokens)
    assert any(not np.array_equal(resp[i * group], resp[i * group + 1])
               for i in range(2))


def test_group_sampling_greedy_is_group_invariant(setup):
    """Temperature 0: every sample of a group must emit the identical
    greedy continuation — and it must match a plain group=1 run."""
    cfg, params = setup
    prompts = jnp.array([[1, 5, 6, 7, 8, 9, 10, 3]], jnp.int32)
    plens = jnp.array([8])
    samp = SamplerConfig(max_new_tokens=5, temperature=0.0)
    t1 = generate(params, prompts, plens, jax.random.key(0), cfg,
                  BF16_ROLLOUT, samp, page_size=4)
    tg = generate(params, prompts, plens, jax.random.key(0), cfg,
                  BF16_ROLLOUT, samp, page_size=4, num_samples_per_prompt=4,
                  shared_prefix_blocks=2)
    one = np.asarray(t1.response_tokens)[0]
    for row in np.asarray(tg.response_tokens):
        np.testing.assert_array_equal(row, one)


def test_group_sampling_ragged_prompts_with_shared_blocks_bound(setup):
    """Ragged prompt lengths: the caller bounds the shared region by the
    shortest prompt (`shared_prefix_blocks`); the fork must still be
    bit-exact against the naive path."""
    cfg, params = setup
    prompts = jnp.array([[1, 5, 6, 7, 8, 0, 0, 0],
                         [1, 9, 10, 11, 12, 4, 13, 14]], jnp.int32)
    plens = jnp.array([5, 8])
    samp = SamplerConfig(max_new_tokens=5, temperature=1.0)
    group = 2
    shared = int(jnp.min(plens)) // 4
    t_g = generate(params, prompts, plens, jax.random.key(11), cfg,
                   BF16_ROLLOUT, samp, page_size=4,
                   num_samples_per_prompt=group,
                   shared_prefix_blocks=shared)
    t_ref = generate(params, jnp.repeat(prompts, group, 0),
                     jnp.repeat(plens, group, 0), jax.random.key(11), cfg,
                     BF16_ROLLOUT, samp, page_size=4)
    np.testing.assert_array_equal(np.asarray(t_g.response_tokens),
                                  np.asarray(t_ref.response_tokens))
    np.testing.assert_array_equal(np.asarray(t_g.rollout_logps),
                                  np.asarray(t_ref.rollout_logps))


def test_group_sampling_ragged_prompts_default_is_safe(setup):
    """Regression: the default shared_prefix_blocks must be safe for
    ragged prompts.  With sharing defaulted off (None -> 0 shared blocks)
    a short prompt's first divergent append can never land in a block
    another sample reads, so the group run must stay bit-exact without
    the caller passing any bound."""
    cfg, params = setup
    prompts = jnp.array([[1, 5, 6, 7, 8, 0, 0, 0],
                         [1, 9, 10, 11, 12, 4, 13, 14]], jnp.int32)
    plens = jnp.array([5, 8])
    samp = SamplerConfig(max_new_tokens=5, temperature=1.0)
    group = 2
    t_g = generate(params, prompts, plens, jax.random.key(11), cfg,
                   BF16_ROLLOUT, samp, page_size=4,
                   num_samples_per_prompt=group)
    t_ref = generate(params, jnp.repeat(prompts, group, 0),
                     jnp.repeat(plens, group, 0), jax.random.key(11), cfg,
                     BF16_ROLLOUT, samp, page_size=4)
    np.testing.assert_array_equal(np.asarray(t_g.response_tokens),
                                  np.asarray(t_ref.response_tokens))
    np.testing.assert_array_equal(np.asarray(t_g.rollout_logps),
                                  np.asarray(t_ref.rollout_logps))


def test_group_pool_layout_is_smaller_than_naive():
    """The point of sharing: the forked layout allocates
    B*shared + B*G*private pool rows, strictly fewer than the naive
    B*G*ceil(max_len/page) — and its tables keep every sample inside its
    own private range beyond the shared prefix."""
    from repro.rl.rollout import _fork_group, _group_layout, _prefill_tables

    b, group, p, g, ps = 2, 4, 8, 7, 4
    fp, priv, w = _group_layout(p, g, ps, 2)
    assert (fp, priv, w) == (2, 2, 4)
    assert _group_layout(p, g, ps, None)[0] == 0   # default: share nothing
    assert _group_layout(p, g, ps, 99)[0] == p // ps  # clamped to the prompt
    pool_rows = b * fp + b * group * priv
    assert pool_rows == 20 < b * group * w == 32   # vs naive identity pool
    pre = np.asarray(_prefill_tables(b, group, w, fp, priv))
    # prompt 1's shared rows then its group-donor private rows
    np.testing.assert_array_equal(pre[1], [2, 3, 4 + 4 * priv,
                                           4 + 4 * priv + 1])
    cache = {"slots": {}, "lengths": jnp.full((b,), p, jnp.int32),
             "block_tables": jnp.zeros((b, w), jnp.int32)}
    forked = _fork_group(cache, b, group, p, ps, fp, priv, w)
    tbl = np.asarray(forked["block_tables"])
    assert tbl.shape == (b * group, w)
    for i in range(b):
        for s in range(group):
            row = tbl[i * group + s]
            np.testing.assert_array_equal(row[:fp], [i * fp, i * fp + 1])
            own0 = b * fp + (i * group + s) * priv
            np.testing.assert_array_equal(row[fp:], [own0, own0 + 1])
    # private ranges are pairwise disjoint across samples
    privs = [tuple(tbl[r, fp:]) for r in range(b * group)]
    assert len(set(privs)) == b * group
    assert np.asarray(forked["lengths"]).tolist() == [p] * (b * group)


def test_group_sampling_moe_routing_shapes(setup):
    """decode routing tracks samples (N rows); prefill routing stays
    per-prompt — the prefix compute is genuinely shared."""
    cfg = _cfg("granite-moe-3b-a800m")
    params = init_params(cfg, jax.random.key(0))
    prompts = jnp.array([[tasks.BOS, 5, 6, 7]], jnp.int32)
    t = generate(params, prompts, jnp.array([4]), jax.random.key(0), cfg,
                 BF16_ROLLOUT, SamplerConfig(max_new_tokens=4),
                 want_routing=True, page_size=4, num_samples_per_prompt=2,
                 shared_prefix_blocks=1)
    pre = t.routing["prefill"]["s0"]
    dec = t.routing["decode"]["s0"]
    assert pre.shape[1] == 1        # (R, B, P, K): one prefill per prompt
    assert dec.shape[2] == 2        # (G, R, N, 1, K): decode per sample