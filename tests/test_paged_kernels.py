"""Paged Pallas serving kernels: interpret-mode parity vs the jnp paths.

Directed (no-hypothesis) coverage for the serving hot path:

  * `fp8_paged_prefill_attention` / length-clamped
    `fp8_paged_decode_attention` vs the pure-jnp oracles, at fp8 AND
    bf16 KV, across ragged tails (context % block_size in {0, 1, BS-1});
  * the stale-table proof: table entries at or past the live region are
    NEVER dereferenced — poisoning the blocks they point at cannot
    change a single output bit;
  * the models-layer routing (`attention_prefill_chunk(use_kernel=...)`,
    `prefill_chunk(use_kernel=...)`) against the jnp fallback, per the
    repo convention: per-step allclose + argmax — argmax asserted only
    where the reference is decisive, since online-softmax kernels may
    legitimately flip near-tied logits;
  * the `KernelConfig` seam (`parse`, engine spelling equivalence).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_serving_config as _cfg
from repro.core import BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT
from repro.core import quant as cq
from repro.data import tasks
from repro.kernels import KernelConfig
from repro.kernels import fp8_kv_attention as attn_mod
from repro.kernels import ref
from repro.models import init_cache, init_params, prefill_chunk
from repro.rl import sync_policy_weights
from repro.serving import ServingEngine

jax.config.update("jax_platform_name", "cpu")

B, KVH, G, D, NBLK, BS, W = 3, 2, 4, 32, 16, 4, 6
POISON = 15                     # pool row reserved for the stale-table proof


def _pool(key, dtype=jnp.float8_e4m3fn):
    ks = jax.random.split(jax.random.key(key), 2)
    k = jax.random.normal(ks[0], (NBLK, BS, KVH, D), jnp.float32)
    v = jax.random.normal(ks[1], (NBLK, BS, KVH, D), jnp.float32)
    if dtype == jnp.bfloat16:
        return k.astype(dtype), v.astype(dtype), jnp.float32(1.0), \
            jnp.float32(1.0)
    k_s = jnp.float32(jnp.abs(k).max() / 448.0)
    v_s = jnp.float32(jnp.abs(v).max() / 448.0)
    return cq.quantize_per_tensor(k, k_s, dtype), \
        cq.quantize_per_tensor(v, v_s, dtype), k_s, v_s


def _tables(key):
    # physical rows drawn below POISON so the poison row is never live
    return jax.random.randint(jax.random.key(key), (B, W), 0, POISON)


def _ragged_lengths(rem: int):
    """Per-slot context lengths with context % BS == rem (full-block,
    one-into-a-block, and one-short-of-full tails)."""
    base = jnp.array([1, 3, 5], jnp.int32) * BS
    return jnp.clip(base + rem, 1, W * BS)


@pytest.mark.parametrize("dtype", [jnp.float8_e4m3fn, jnp.bfloat16],
                         ids=["fp8", "bf16"])
@pytest.mark.parametrize("rem", [0, 1, BS - 1])
def test_paged_decode_clamped_matches_ref(dtype, rem):
    kq, vq, k_s, v_s = _pool(7, dtype)
    q = jax.random.normal(jax.random.key(8), (B, KVH, G, D), jnp.bfloat16)
    tbl = _tables(9)
    lengths = _ragged_lengths(rem)
    out_k = attn_mod.fp8_paged_decode_attention(
        q, kq, vq, k_s, v_s, tbl, lengths, interpret=True)
    out_r = ref.fp8_paged_decode_attention_ref(
        q, kq, vq, k_s, v_s, tbl, lengths)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float8_e4m3fn, jnp.bfloat16],
                         ids=["fp8", "bf16"])
@pytest.mark.parametrize("rem", [0, 1, BS - 1])
def test_paged_prefill_matches_ref(dtype, rem):
    c = 5
    kq, vq, k_s, v_s = _pool(10, dtype)
    qc = jax.random.normal(jax.random.key(11), (B, c, KVH, G, D),
                           jnp.bfloat16)
    lengths = _ragged_lengths(rem)
    start = jnp.maximum(lengths - jnp.array([1, c, 3]), 0)   # ragged chunks
    tbl = _tables(12)
    out_k = attn_mod.fp8_paged_prefill_attention(
        qc, kq, vq, k_s, v_s, tbl, start, lengths, interpret=True)
    out_r = ref.fp8_paged_prefill_attention_ref(
        qc, kq, vq, k_s, v_s, tbl, start, lengths)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=2e-2, atol=2e-2)


def _poisoned(kq, vq):
    big = jnp.float32(448)
    return kq.at[POISON].set(big.astype(kq.dtype)), \
        vq.at[POISON].set(big.astype(vq.dtype))


@pytest.mark.parametrize("rem", [0, 1, BS - 1])
def test_paged_decode_never_reads_stale_table_entries(rem):
    """Entries at or past ceil(context/BS) may hold ANY id (stale blocks
    reassigned to another request, trash, garbage): the clamped index map
    never dereferences them, so poisoning the blocks they point at must
    not change one bit of output."""
    kq, vq, k_s, v_s = _pool(13)
    q = jax.random.normal(jax.random.key(14), (B, KVH, G, D), jnp.bfloat16)
    lengths = _ragged_lengths(rem)
    tbl = np.asarray(_tables(15)).copy()
    live = np.asarray((lengths + BS - 1) // BS)
    for i in range(B):
        tbl[i, live[i]:] = POISON          # stale ids past the live region
    kp, vp = _poisoned(kq, vq)
    out_p = attn_mod.fp8_paged_decode_attention(
        q, kp, vp, k_s, v_s, jnp.asarray(tbl), lengths, interpret=True)
    out_c = attn_mod.fp8_paged_decode_attention(
        q, kq, vq, k_s, v_s, jnp.asarray(tbl), lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(out_p, np.float32),
                                  np.asarray(out_c, np.float32))


@pytest.mark.parametrize("rem", [0, 1, BS - 1])
def test_paged_prefill_never_reads_stale_table_entries(rem):
    c = 4
    kq, vq, k_s, v_s = _pool(16)
    qc = jax.random.normal(jax.random.key(17), (B, c, KVH, G, D),
                           jnp.bfloat16)
    lengths = _ragged_lengths(rem)
    start = jnp.maximum(lengths - c, 0)
    tbl = np.asarray(_tables(18)).copy()
    ctx = np.minimum(np.asarray(start) + c, np.asarray(lengths))
    live = np.maximum((ctx + BS - 1) // BS, 1)
    for i in range(B):
        tbl[i, live[i]:] = POISON
    kp, vp = _poisoned(kq, vq)
    out_p = attn_mod.fp8_paged_prefill_attention(
        qc, kp, vp, k_s, v_s, jnp.asarray(tbl), start, lengths,
        interpret=True)
    out_c = attn_mod.fp8_paged_prefill_attention(
        qc, kq, vq, k_s, v_s, jnp.asarray(tbl), start, lengths,
        interpret=True)
    np.testing.assert_array_equal(np.asarray(out_p, np.float32),
                                  np.asarray(out_c, np.float32))


# ---------------------------------------------------------------------------
# models-layer routing: chunk attention + model logits through the kernel
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    return cfg, init_params(cfg, jax.random.key(0))


@pytest.mark.parametrize("precision", [BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT],
                         ids=["bf16", "fp8"])
def test_attention_prefill_chunk_kernel_matches_jnp(setup, precision):
    """Single attention layer: the kernel-vs-gather residual is pure
    flash-vs-full accumulation noise (<2e-2) before any depth-wise
    amplification."""
    from repro.models import attention as am
    cfg, params = setup
    roll, _ = sync_policy_weights(params, precision)
    p_attn = jax.tree.map(lambda a: a[0], roll["blocks"]["s0"]["attn"])
    tbl = jnp.array([[0, 1, -1], [2, 3, -1]], jnp.int32)
    x1 = jax.random.normal(jax.random.key(5), (2, 4, cfg.d_model),
                           jnp.bfloat16)
    x2 = jax.random.normal(jax.random.key(6), (2, 4, cfg.d_model),
                           jnp.bfloat16)
    outs = {}
    for uk in (False, True):
        cache = am.init_paged_kv_cache(4, 4, cfg.n_kv_heads, cfg.d_head,
                                       precision)
        _, cache = am.attention_prefill_chunk(
            x1, p_attn, cfg, cache, precision,
            start=jnp.zeros((2,), jnp.int32), lengths=jnp.array([4, 4]),
            block_tables=tbl, use_kernel=uk)
        prec2 = precision.replace(calculate_kv_scales=False)
        o2, _ = am.attention_prefill_chunk(
            x2, p_attn, cfg, cache, prec2,
            start=jnp.array([4, 4], jnp.int32), lengths=jnp.array([5, 7]),
            block_tables=tbl, use_kernel=uk)
        outs[uk] = np.asarray(o2, np.float32)
    # ragged rows past `lengths` are garbage in the jnp path and zeros in
    # the kernel path; the caller never reads them — compare valid rows
    for b, n_valid in enumerate((1, 3)):
        np.testing.assert_allclose(outs[True][b, :n_valid],
                                   outs[False][b, :n_valid],
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("precision", [BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT],
                         ids=["bf16", "fp8"])
def test_prefill_chunk_model_logits_parity(setup, precision):
    """prefill_chunk(use_kernel=True) vs the jnp path at the LOGITS level
    (two layers + unembed amplify the attention noise ~10x): allclose at
    the amplified tolerance, argmax asserted where the reference's top-2
    gap is decisive (near-ties may legitimately flip — the documented
    online-softmax caveat)."""
    cfg, params = setup
    roll, _ = sync_policy_weights(params, precision)
    logits = {}
    for uk in (False, True):
        cache = init_cache(cfg, 2, 16, precision, page_size=4)
        t1 = jnp.array([[1, 5, 6, 7], [1, 9, 10, 11]], jnp.int32)
        lg1, cache = prefill_chunk(
            roll, t1, jnp.zeros((2,), jnp.int32),
            jnp.array([4, 4], jnp.int32), cache, cfg, precision,
            use_kernel=uk)
        t2 = jnp.array([[8, 0, 0, 0], [12, 13, 0, 0]], jnp.int32)
        lg2, cache = prefill_chunk(
            roll, t2, jnp.array([4, 4], jnp.int32),
            jnp.array([1, 2], jnp.int32), cache, cfg, precision,
            use_kernel=uk)
        logits[uk] = (np.asarray(lg1, np.float32),
                      np.asarray(lg2, np.float32))
    for a, b in zip(logits[True], logits[False]):
        np.testing.assert_allclose(a, b, rtol=5e-2, atol=0.15)
        for row_k, row_j in zip(a, b):
            srt = np.sort(row_j)[::-1]
            if srt[0] - srt[1] > 0.3:          # decisive reference
                assert row_k.argmax() == row_j.argmax()


# ---------------------------------------------------------------------------
# KernelConfig seam
# ---------------------------------------------------------------------------


def test_kernel_config_parse():
    assert KernelConfig.parse("off") == KernelConfig()
    assert KernelConfig.parse("decode") == KernelConfig(decode=True)
    assert KernelConfig.parse("prefill") == KernelConfig(prefill=True)
    assert KernelConfig.parse("all") == KernelConfig(prefill=True,
                                                     decode=True)
    kc = KernelConfig(decode=True)
    assert KernelConfig.parse(kc) is kc
    assert not KernelConfig().any and KernelConfig(prefill=True).any
    with pytest.raises(ValueError, match="unknown kernel_config"):
        KernelConfig.parse("paged")


def test_engine_kernel_config_spellings_equivalent(setup):
    """kernel_config="decode" is the same mechanism as the legacy
    decode_kernel="paged" — identical tokens, same flags."""
    cfg, params = setup
    prec = FP8_KV_ONLY_ROLLOUT
    roll, _ = sync_policy_weights(params, prec)
    outs = {}
    for name, kw in (("legacy", dict(decode_kernel="paged")),
                     ("config", dict(kernel_config="decode"))):
        eng = ServingEngine(roll, cfg, prec, max_slots=2, max_seq_len=32,
                            **kw)
        assert eng.kernels == KernelConfig(decode=True)
        for i in range(3):
            eng.submit(tasks.random_prompt(i, 7), max_new=5, rid=i)
        rep = eng.run(max_steps=100)
        assert len(rep.completed) == 3
        outs[name] = {r.rid: list(r.generated) for r in rep.completed}
    assert outs["legacy"] == outs["config"]
    with pytest.raises(AssertionError, match="not both"):
        ServingEngine(roll, cfg, prec, decode_kernel="paged",
                      kernel_config="all")


def test_engine_kernel_all_serves_chunked_trace(setup):
    """kernel_config="all" + chunked prefill serves a full trace through
    both Pallas kernels end-to-end (the hot-path configuration); the
    trace-level parity and preemption coverage live in
    benchmarks/kernel_hotpath.py and the scheduler hypothesis property."""
    cfg, params = setup
    prec = FP8_KV_ONLY_ROLLOUT
    roll, _ = sync_policy_weights(params, prec)
    eng = ServingEngine(roll, cfg, prec, max_slots=2, max_seq_len=48,
                        prefill_chunk=4, kernel_config="all", eos_id=None)
    eng.submit(tasks.random_prompt(1, 25), max_new=6, rid=0)  # > prompt_pad
    eng.submit(tasks.random_prompt(2, 9), max_new=6, rid=1)
    rep = eng.run(max_steps=100)
    assert len(rep.completed) == 2
    assert rep.prefill_chunks >= 4
    assert eng.block_mgr.blocks_in_use == 0
