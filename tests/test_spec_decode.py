"""Speculative decoding: proposer, rejection sampler, and the serving
engine's Draft/Verify path.

Three layers of exactness guarantees, cheapest first:

  * `rejection_sample` in isolation — greedy output equals the argmax
    chain token-for-token, and at temperature > 0 the emitted-token
    distribution is statistically indistinguishable (chi-square) from
    sampling the target distribution directly, per position.
  * the sampler plumbing the engine shares with the rollout path —
    top-k truncation keeps EXACTLY k tokens (ties broken by index), and
    `top_k` actually reaches every serving `sample()` call (the
    silently-dropped-kwarg regression).
  * the engine end-to-end — greedy completions with speculation on are
    bit-exact vs the non-speculative engine, including under forced
    mid-run preemption (the KV-rewind + swap-trim contract), and a
    capacity-stuck trace reports `stalled` instead of fake success.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_serving_config as _cfg
from repro.core import BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT
from repro.core.sampling import rejection_sample, sample, sampling_logits
from repro.data import tasks
from repro.models import init_params
from repro.rl import SamplerConfig, generate, sync_policy_weights
from repro.serving import (
    NGramProposer,
    ServingEngine,
    SpecConfig,
    kv_bytes_per_token,
)

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _req(prompt, generated=()):
    """Duck-typed stand-in for serving.Request (the proposer reads only
    .prompt and .generated)."""
    return types.SimpleNamespace(prompt=list(prompt),
                                 generated=list(generated))


def _spec_prompts(n, seed=0, pattern_len=4, repeats=3):
    """Repetitive-suffix prompts the n-gram proposer locks onto."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        pat = rng.integers(4, 19, size=pattern_len)
        out.append(np.concatenate(
            [[tasks.BOS], np.tile(pat, repeats)]).astype(np.int32))
    return out


# ---------------------------------------------------------------------------
# n-gram proposer
# ---------------------------------------------------------------------------

def test_ngram_proposer_continues_repeated_pattern():
    p = NGramProposer(SpecConfig(num_draft_tokens=3))
    # suffix [5,6,7] recurs at the start: continuation follows it
    assert p.propose(_req([1, 5, 6, 7, 5, 6, 7]), 3) == [5, 6, 7]


def test_ngram_proposer_self_extends_constant_run():
    # greedy decode's degenerate case: a constant-token run.  The only
    # match overlaps the suffix end and yields 1 token per lookup; the
    # self-extending re-match must still fill all k drafts.
    p = NGramProposer(SpecConfig(num_draft_tokens=4))
    assert p.propose(_req([1, 9], [9, 9]), 4) == [9, 9, 9, 9]


def test_ngram_proposer_extends_through_cycle():
    p = NGramProposer(SpecConfig(num_draft_tokens=5))
    # context ends mid-cycle [4,5,6]; drafts keep cycling
    assert p.propose(_req([1, 4, 5, 6], [4, 5, 6]), 5) == [4, 5, 6, 4, 5]


def test_ngram_proposer_no_match_returns_empty():
    p = NGramProposer(SpecConfig())
    assert p.propose(_req([1, 2, 3, 4, 5]), 4) == []


# ---------------------------------------------------------------------------
# rejection sampling: greedy = argmax chain, bit-exact
# ---------------------------------------------------------------------------

def test_rejection_sample_greedy_matches_argmax_chain():
    rng = np.random.default_rng(0)
    for trial in range(25):
        k = int(rng.integers(1, 5))
        logits = rng.normal(size=(k + 1, 12)).astype(np.float32)
        drafts = rng.integers(0, 12, size=k)
        greedy = logits.argmax(-1)
        # expected: accepted argmax prefix + corrected token on first
        # mismatch, or the bonus token when every draft matches
        exp, exp_acc = [], 0
        for i in range(k):
            exp.append(int(greedy[i]))
            if int(drafts[i]) != int(greedy[i]):
                break
            exp_acc += 1
        else:
            exp.append(int(greedy[k]))
        toks, n_acc, logps = rejection_sample(
            jnp.asarray(logits), list(drafts), jax.random.key(trial), 0.0)
        assert (toks, n_acc) == (exp, exp_acc)
        # logps follow the untempered-softmax greedy convention of sample()
        ref = jax.nn.log_softmax(jnp.asarray(logits), -1)
        for i, t in enumerate(toks):
            assert logps[i] == pytest.approx(float(ref[i, t]))


# ---------------------------------------------------------------------------
# rejection sampling: statistical exactness at temperature > 0
# ---------------------------------------------------------------------------

def _chi2(counts, probs, n):
    """Pearson chi-square of `counts` against expected n*probs (over the
    support only)."""
    stat = 0.0
    for t, p in enumerate(probs):
        if p > 1e-9:
            stat += (counts.get(t, 0) - n * p) ** 2 / (n * p)
    return stat


def test_rejection_sample_output_distribution_matches_target():
    """The emitted-token distribution at every position equals sampling
    the target distribution directly (the Leviathan one-hot-q identity:
    p(d) + (1-p(d)) * p(x)/(1-p(d)) = p(x)) — accept/reject/resample
    must leave NO statistical fingerprint.  Position i is compared
    conditionally on reaching it (draft prefix accepted)."""
    temperature, top_k, v, n = 0.7, 5, 12, 1500
    rng = np.random.default_rng(7)
    logits = jnp.asarray(rng.normal(size=(3, v)), jnp.float32)
    probs = np.asarray(
        jax.nn.softmax(sampling_logits(logits, temperature, top_k), -1))
    # draft 0 = mode of row 0 (often accepted -> position 1 well sampled);
    # draft 1 = a mid-probability token (exercises both branches)
    d0 = int(probs[0].argmax())
    d1 = int(np.argsort(probs[1])[-3])
    pos_counts = [{}, {}]
    reached = [0, 0]
    for s in range(n):
        toks, n_acc, _ = rejection_sample(
            logits, [d0, d1], jax.random.key(s), temperature, top_k)
        for i in range(min(len(toks), 2)):
            pos_counts[i][toks[i]] = pos_counts[i].get(toks[i], 0) + 1
            reached[i] += 1
        # support respected: only top-k tokens can ever be emitted
        for i, t in enumerate(toks):
            assert probs[i, t] > 0.0
    # position 0 is unconditional; position 1 is conditioned on accepting
    # d0, which leaves the row-1 target distribution untouched
    for i in range(2):
        assert reached[i] > 400
        stat = _chi2(pos_counts[i], probs[i], reached[i])
        # df = top_k - 1 = 4; chi2_{0.999}(4) = 18.5 — loose enough to
        # be seed-stable, tight enough to catch a biased sampler
        assert stat < 18.5, (i, stat, pos_counts[i])


def test_rejection_sample_rejects_unlikely_drafts():
    """A draft token OUTSIDE the top-k support is always rejected and
    never emitted at its position."""
    temperature, top_k, v = 0.7, 3, 10
    logits = jnp.asarray(np.linspace(3.0, 0.0, v)[None, :].repeat(2, 0),
                         jnp.float32)
    dead = v - 1          # lowest logit: truncated out of the support
    for s in range(40):
        toks, n_acc, _ = rejection_sample(
            logits, [dead], jax.random.key(s), temperature, top_k)
        assert n_acc == 0 and toks[0] != dead and toks[0] < top_k


# ---------------------------------------------------------------------------
# top-k truncation: exactly k survivors (satellite: tie handling)
# ---------------------------------------------------------------------------

def test_top_k_keeps_exactly_k_under_ties():
    # three tokens tied at the k-th logit: `scaled < thresh` kept them
    # all; the fixed mask must keep exactly k, lower index first
    logits = jnp.array([3.0, 2.0, 2.0, 2.0, 1.0])
    out = np.asarray(sampling_logits(logits, 1.0, top_k=2))
    kept = np.flatnonzero(out > -1e29)
    np.testing.assert_array_equal(kept, [0, 1])
    p = np.asarray(jax.nn.softmax(jnp.asarray(out)))
    assert p[kept].sum() == pytest.approx(1.0)


def test_top_k_exact_support_property():
    """Over random heavily-tied logits: the truncated support always has
    exactly k tokens, matches the deterministic (-value, index) order,
    renormalizes to 1, and sampling never leaves it."""
    rng = np.random.default_rng(3)
    v = 8
    for trial in range(30):
        k = int(rng.integers(1, v + 1))
        logits = jnp.asarray(rng.integers(0, 3, size=v), jnp.float32)
        out = np.asarray(sampling_logits(logits, 1.0, top_k=k))
        kept = np.flatnonzero(out > -1e29)
        assert len(kept) == k, (trial, k, kept)
        order = sorted(range(v), key=lambda i: (-float(logits[i]), i))
        assert sorted(kept) == sorted(order[:k])
        assert np.asarray(jax.nn.softmax(jnp.asarray(out)))[kept].sum() \
            == pytest.approx(1.0)
        tok, _ = sample(logits, jax.random.key(trial), 1.0, top_k=k)
        assert int(tok) in kept


# ---------------------------------------------------------------------------
# top_k threading (satellite: serving dropped the kwarg)
# ---------------------------------------------------------------------------

def test_serving_threads_top_k_to_sampler(setup):
    """temperature=1, top_k=1 IS greedy (top-1 truncation leaves only
    the argmax).  The pre-fix engine dropped `top_k` at all three
    sample() call sites, so this ran full-softmax sampling instead."""
    cfg, params = setup
    prompts = _spec_prompts(3, seed=2)
    outs = {}
    for name, kw in (("greedy", dict(temperature=0.0)),
                     ("top1", dict(temperature=1.0, top_k=1))):
        eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=4,
                            max_seq_len=32, prefill_chunk=4, eos_id=None,
                            **kw)
        for i, p in enumerate(prompts):
            eng.submit(p, max_new=6, rid=i)
        rep = eng.run(max_steps=200)
        assert len(rep.completed) == len(prompts) and not rep.stalled
        outs[name] = {r.rid: list(r.generated) for r in rep.completed}
    assert outs["top1"] == outs["greedy"]


def test_rollout_vs_serving_top_k_parity(setup):
    """Rollout and serving share one sampler contract: with identical
    sampler settings (here top_k=1, where the truncated distribution is
    deterministic) both engines emit the same tokens for the same
    prompt."""
    cfg, params = setup
    prompt = np.array([tasks.BOS, 5, 6, 7, 8], np.int32)
    t = generate(params, jnp.asarray(prompt)[None, :],
                 jnp.array([len(prompt)]), jax.random.key(0), cfg,
                 BF16_ROLLOUT,
                 SamplerConfig(max_new_tokens=6, temperature=1.0, top_k=1))
    n = int(t.response_lengths[0])
    roll_toks = [int(x) for x in np.asarray(t.response_tokens)[0, :n]]

    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=32, temperature=1.0, top_k=1)
    eng.submit(prompt, max_new=6, rid=0)
    rep = eng.run(max_steps=100)
    assert list(rep.completed[0].generated) == roll_toks


# ---------------------------------------------------------------------------
# engine end-to-end: speculation is bit-exact and actually speculates
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", [BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT],
                         ids=["bf16", "fp8"])
def test_spec_decode_greedy_bit_exact_vs_plain(setup, precision):
    cfg, params = setup
    params_r = params
    if precision.kv_quantized:
        params_r, _ = sync_policy_weights(params, precision)
    prompts = _spec_prompts(3, seed=0)
    outs = {}
    for spec in (None, SpecConfig(num_draft_tokens=4)):
        eng = ServingEngine(params_r, cfg, precision, max_slots=4,
                            max_seq_len=48, prefill_chunk=4, eos_id=None,
                            spec=spec)
        for i, p in enumerate(prompts):
            eng.submit(p, max_new=8, rid=i)
        rep = eng.run(max_steps=300)
        assert len(rep.completed) == len(prompts) and not rep.stalled
        outs[spec is not None] = \
            {r.rid: list(r.generated) for r in rep.completed}
        if spec is not None:
            # the repetitive trace must actually speculate, and win
            assert rep.spec_steps > 0 and rep.accepted_tokens > 0
            assert rep.spec_tokens_per_step > 1.0
        assert eng.block_mgr.blocks_in_use == 0
    assert outs[True] == outs[False]


def test_spec_decode_rewind_survives_forced_preemption(setup):
    """Preempting slots that have speculated (rewound verifies leave
    them owning blocks past cached_tokens) must swap out, resume, and
    finish bit-exact — the swap snapshot is trimmed to the rewound
    length and re-admission restores the exact pending position."""
    cfg, params = setup
    prompts = _spec_prompts(4, seed=1)

    def serve(shrink):
        eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=4,
                            max_seq_len=48, prefill_chunk=4, eos_id=None,
                            admission="ondemand",
                            spec=SpecConfig(num_draft_tokens=4))
        for i, p in enumerate(prompts):
            eng.submit(p, max_new=10, rid=i)
        if shrink:
            # let speculation start, then halve the budget mid-flight so
            # actively-speculating slots get evicted
            for _ in range(40):
                eng.step()
                if eng.stats["spec_steps"] >= 1:
                    break
            assert eng.stats["spec_steps"] >= 1
            # 12 blocks: enough for any single request (6 blocks + spec
            # growth) but nowhere near 4 concurrent ones
            eng.budget_tokens = 12 * eng.block_mgr.block_size
        rep = eng.run(max_steps=400)
        assert len(rep.completed) == len(prompts) and not rep.stalled
        assert eng.block_mgr.blocks_in_use == 0
        return rep

    ref = serve(shrink=False)
    rep = serve(shrink=True)
    assert rep.preemptions >= 1          # the shrink actually bit
    assert rep.spec_steps >= 1
    assert {r.rid: list(r.generated) for r in rep.completed} == \
        {r.rid: list(r.generated) for r in ref.completed}


# ---------------------------------------------------------------------------
# stalled reporting (satellite: partial report looked like success)
# ---------------------------------------------------------------------------

def test_run_surfaces_capacity_stuck_as_stalled(setup):
    cfg, params = setup
    per = kv_bytes_per_token(cfg, BF16_ROLLOUT)
    # one block of budget: reserve admission can never place the request
    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=32, kv_budget_bytes=per * 4)
    eng.submit(np.array([tasks.BOS, 5, 6, 7, 8, 9, 10, 11], np.int32),
               max_new=8, rid=0)
    rep = eng.run(max_steps=50)
    assert rep.stalled
    assert len(rep.completed) == 0
    assert len(eng.queue) == 1           # the request is still waiting
