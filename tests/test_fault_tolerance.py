"""Fault-tolerant fleet serving: deterministic injection, failover with
exactly-once token delivery, atomic weight pushes, and the no-silent-loss
abort paths (stall / deadline / no-survivors)."""

import jax
import numpy as np
import pytest

from repro.configs import tiny_serving_config
from repro.core import FP8_LINEAR_ROLLOUT
from repro.data import tasks
from repro.models import init_params
from repro.obs import events as ev
from repro.obs.tracer import StepTracer
from repro.rl import WeightSyncer, sync_policy_weights
from repro.serving import (
    FINISH_ABORT,
    FINISH_LENGTH,
    CrashFault,
    FaultInjector,
    FaultPlan,
    HostCopyFault,
    InstallFault,
    ReplicaCrash,
    ServingEngine,
    ServingFrontend,
    WeightInstallError,
)

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_serving_config()
    params = init_params(cfg, jax.random.key(0))
    prec = FP8_LINEAR_ROLLOUT
    roll, _ = sync_policy_weights(params, prec)
    return cfg, params, prec, roll


def _mk_engine(setup, *, seed=0, version=0, **kw):
    cfg, _params, prec, roll = setup
    kw.setdefault("max_slots", 3)
    kw.setdefault("max_seq_len", 48)
    kw.setdefault("eos_id", None)
    # chunked prefill: failover replays original_prompt + streamed as
    # one longer prompt, which must clear admission
    kw.setdefault("prefill_chunk", 8)
    return ServingEngine(roll, cfg, prec, temperature=0.0, seed=seed,
                         weight_version=version, **kw)


def _mk_fleet(setup, *, replicas=2, faults=None, trace=False, **kw):
    engines = [
        _mk_engine(setup, seed=i, faults=faults,
                   tracer=StepTracer(replica=i) if trace else None, **kw)
        for i in range(replicas)]
    return ServingFrontend(
        engines, tracer=StepTracer(replica=-1) if trace else None)


def _prompt(seed, plen):
    rng = np.random.default_rng(seed)
    return np.concatenate(
        [[tasks.BOS], rng.integers(4, 19, size=plen - 1)]).astype(np.int32)


def _next_version(setup, *, scale=1.1):
    cfg, params, prec, _ = setup
    nudged = jax.tree.map(lambda x: x * scale, params)
    roll, _ = sync_policy_weights(nudged, prec)
    return roll


def _run_collect(fe, max_steps=600):
    finals = {}
    for _ in range(max_steps):
        if not fe.has_work():
            break
        for out in fe.step():
            if out.finished:
                finals[out.rid] = out
    return finals


# ---------------------------------------------------------------------------
# injector mechanics
# ---------------------------------------------------------------------------

def test_empty_plan_injector_is_inert(setup):
    eng = _mk_engine(setup, faults=FaultInjector(FaultPlan()))
    eng.submit(_prompt(0, 6), max_new=4)
    rep = eng.run(max_steps=200)
    assert len(rep.completed) == 1 and not rep.stalled


def test_crash_fires_once_at_scheduled_step(setup):
    inj = FaultInjector(FaultPlan(crashes=(
        CrashFault(replica=0, step=2, transient=False),)))
    eng = _mk_engine(setup, faults=inj)
    eng.submit(_prompt(0, 6), max_new=6)
    eng.step()
    eng.step()
    with pytest.raises(ReplicaCrash):
        eng.step()
    assert inj.injected["crashes"] == 1
    eng.step()                       # one-shot: does not re-fire
    assert inj.injected["crashes"] == 1


def test_install_fault_burns_bounded_budget(setup):
    inj = FaultInjector(FaultPlan(installs=(
        InstallFault(replica=0, version=1, times=2),)))
    eng = _mk_engine(setup, faults=inj)
    for _ in range(2):
        with pytest.raises(WeightInstallError):
            eng.install_weights(eng.params, 1)
    assert eng.weight_version == 0   # raise-before-mutate: replica-atomic
    eng.install_weights(eng.params, 1)
    assert eng.weight_version == 1
    assert inj.injected["install_failures"] == 2


def test_random_plan_keeps_a_survivor():
    for seed in range(40):
        plan = FaultPlan.random(seed, replicas=3, max_step=10, n_crashes=3)
        permanent = sum(1 for c in plan.crashes if not c.transient)
        assert permanent <= 2


# ---------------------------------------------------------------------------
# failover: exactly-once delivery
# ---------------------------------------------------------------------------

def test_failover_is_bit_exact_and_exactly_once(setup):
    prompts = [_prompt(s, 6 + s % 4) for s in range(5)]
    kw = dict(replicas=2, max_slots=2)

    fe0 = _mk_fleet(setup, **kw)
    for i, p in enumerate(prompts):
        fe0.submit(p, max_new=6, rid=i)
    oracle = _run_collect(fe0)

    inj = FaultInjector(FaultPlan(crashes=(
        CrashFault(replica=0, step=3, transient=False),)))
    fe1 = _mk_fleet(setup, faults=inj, trace=True, **kw)
    for i, p in enumerate(prompts):
        fe1.submit(p, max_new=6, rid=i)
    got = _run_collect(fe1)

    assert inj.injected["crashes"] == 1
    assert sorted(got) == sorted(oracle)          # zero requests lost
    for rid in oracle:
        o, g = oracle[rid].output, got[rid].output
        assert g.token_ids == o.token_ids         # bit-exact, no dup/drop
        assert g.versions == o.versions           # exact attribution
        assert g.finish_reason == o.finish_reason
    assert fe1.redispatches >= 1 and fe1.replayed_tokens >= 0
    red = [e for e in fe1.tracer.events
           if isinstance(e, ev.RedispatchEvent)]
    assert len(red) == fe1.redispatches
    assert sum(e.replayed_tokens for e in red) == fe1.replayed_tokens


def test_streamed_tokens_never_reemitted_across_failover(setup):
    """The incremental delta streams concatenate to exactly the final
    stream — replayed tokens never reappear in a delta."""
    inj = FaultInjector(FaultPlan(crashes=(
        CrashFault(replica=0, step=4, transient=False),)))
    fe = _mk_fleet(setup, replicas=2, max_slots=2, faults=inj)
    for i in range(4):
        fe.submit(_prompt(i, 7), max_new=6, rid=i)
    deltas = {i: [] for i in range(4)}
    finals = {}
    for _ in range(400):
        if not fe.has_work():
            break
        for out in fe.step():
            deltas[out.rid].extend(out.new_token_ids)
            if out.finished:
                finals[out.rid] = out
    assert inj.injected["crashes"] == 1
    assert len(finals) == 4
    for rid, out in finals.items():
        assert deltas[rid] == out.output.token_ids
        assert len(out.output.token_ids) == 6


def test_transient_crash_rejoins_and_serves(setup):
    inj = FaultInjector(FaultPlan(crashes=(
        CrashFault(replica=1, step=1, transient=True, down_steps=2),)))
    fe = _mk_fleet(setup, replicas=2, max_slots=2, faults=inj,
                   trace=True)
    for i in range(4):
        fe.submit(_prompt(i, 6), max_new=5, rid=i)
    finals = _run_collect(fe)
    assert len(finals) == 4
    assert fe.healthy_replicas == 2              # it came back
    ups = [e for e in fe.tracer.events if isinstance(e, ev.ReplicaUpEvent)]
    assert len(ups) == 1 and ups[0].version == fe.weight_version
    # the rejoined replica serves new work
    rid = fe.submit(_prompt(9, 6), max_new=4)
    assert fe._tracked[rid].replica == 1         # empty replica wins dispatch
    finals = _run_collect(fe)
    assert len(finals[rid].output.token_ids) == 4


def test_no_survivor_aborts_instead_of_losing(setup):
    inj = FaultInjector(FaultPlan(crashes=(
        CrashFault(replica=0, step=2, transient=False),)))
    fe = _mk_fleet(setup, replicas=1, faults=inj)
    fe.submit(_prompt(0, 6), max_new=6, rid=0)
    finals = _run_collect(fe)
    assert finals[0].output.finish_reason == FINISH_ABORT
    assert fe.aborted == 1 and fe.healthy_replicas == 0
    with pytest.raises(RuntimeError, match="no healthy replica"):
        fe.submit(_prompt(1, 6), max_new=4)


# ---------------------------------------------------------------------------
# atomic pushes: retry, quarantine, no version split
# ---------------------------------------------------------------------------

def test_transient_install_failure_absorbed_by_retry(setup):
    inj = FaultInjector(FaultPlan(installs=(
        InstallFault(replica=0, version=1, times=1),)))
    fe = _mk_fleet(setup, replicas=2, faults=inj)
    fe.submit(_prompt(0, 6), max_new=6, rid=0)
    fe.step()
    fe.update_weights(_next_version(setup), 1)
    assert fe.push_retries == 1
    assert fe.healthy_replicas == 2              # nobody quarantined
    assert all(e.weight_version == 1 for e in fe.engines)
    finals = _run_collect(fe)
    assert len(finals) == 1


def test_permanent_install_failure_quarantines_not_splits(setup):
    inj = FaultInjector(FaultPlan(installs=(
        InstallFault(replica=1, version=1, times=-1),)))
    fe = _mk_fleet(setup, replicas=2, max_slots=2, faults=inj,
                   trace=True)
    for i in range(4):
        fe.submit(_prompt(i, 6), max_new=6, rid=i)
    fe.step()
    fe.update_weights(_next_version(setup), 1)
    assert fe.health[1] == "quarantined"
    assert fe.engines[0].weight_version == 1 == fe.weight_version
    assert fe.redispatches >= 1                  # its work moved over
    quars = [e for e in fe.tracer.events
             if isinstance(e, ev.QuarantineEvent)]
    assert len(quars) == 1
    finals = _run_collect(fe)
    assert len(finals) == 4                      # zero lost
    assert all(o.output.finish_reason == FINISH_LENGTH
               for o in finals.values())
    assert all(list(o.output.versions) == sorted(o.output.versions)
               for o in finals.values())


def test_staged_push_failure_resolved_at_boundary(setup):
    inj = FaultInjector(FaultPlan(installs=(
        InstallFault(replica=0, version=1, times=1),)))
    fe = _mk_fleet(setup, replicas=2, faults=inj)
    # work on BOTH replicas: staged installs only commit at a step
    # boundary, and an idle replica never reaches one
    fe.submit(_prompt(0, 6), max_new=8, rid=0)
    fe.submit(_prompt(1, 6), max_new=8, rid=1)
    fe.step()
    fe.stage_weights(_next_version(setup), 1)
    finals = _run_collect(fe)
    assert len(finals) == 2
    assert fe.push_retries == 1                  # boundary failure retried
    assert fe.healthy_replicas == 2
    assert all(e.weight_version == 1 for e in fe.engines)


def test_quarantined_replica_excluded_from_dispatch(setup):
    inj = FaultInjector(FaultPlan(installs=(
        InstallFault(replica=0, version=1, times=-1),)))
    fe = _mk_fleet(setup, replicas=2, faults=inj)
    fe.update_weights(_next_version(setup), 1)
    assert fe.health == ["quarantined", "healthy"]
    for i in range(3):
        rid = fe.submit(_prompt(i, 6), max_new=4)
        assert fe._tracked[rid].replica == 1
    finals = _run_collect(fe)
    assert len(finals) == 3                      # N-1 degradation works


# ---------------------------------------------------------------------------
# WeightSyncer: failure handling without version desync
# ---------------------------------------------------------------------------

class _FlakyFleet:
    """Fleet double whose update_weights fails `fail` times."""

    def __init__(self, fail):
        self.fail = fail
        self.installed = []

    def update_weights(self, params, version):
        if self.fail > 0:
            self.fail -= 1
            raise WeightInstallError(0, version)
        self.installed.append(version)


def test_push_to_mints_version_only_on_success(setup):
    cfg, params, prec, _ = setup
    syncer = WeightSyncer(prec, install_retries=2)
    fleet = _FlakyFleet(fail=2)
    vw = syncer.push_to(params, fleet)           # 2 failures absorbed
    assert vw.version == 1 and syncer.version == 1
    assert fleet.installed == [1]
    assert syncer.push_failures == 2


def test_push_to_failure_leaves_version_untouched(setup):
    cfg, params, prec, _ = setup
    syncer = WeightSyncer(prec, install_retries=1)
    fleet = _FlakyFleet(fail=99)
    with pytest.raises(WeightInstallError):
        syncer.push_to(params, fleet)
    assert syncer.version == 0                   # no skip, no split
    assert fleet.installed == []
    ok = _FlakyFleet(fail=0)
    vw = syncer.push_to(params, ok)              # next push reuses v1
    assert vw.version == 1 and ok.installed == [1]


# ---------------------------------------------------------------------------
# silent-loss fixes: stall / deadline aborts, cancel frees blocks
# ---------------------------------------------------------------------------

def test_deadline_tokens_aborts_on_fleet_clock(setup):
    fe = _mk_fleet(setup, replicas=1)
    fe.submit(_prompt(0, 6), max_new=40, rid=0, deadline_tokens=8)
    finals = _run_collect(fe)
    out = finals[0]
    assert out.output.finish_reason == FINISH_ABORT
    assert 0 < len(out.output.token_ids) < 40    # partial stream delivered
    assert fe.engines[0].block_mgr.blocks_in_use == 0   # blocks freed


def test_stall_aborts_instead_of_silent_loss(setup):
    # a prompt too big for the pool: admission never succeeds, the old
    # frontend dropped the request from the report entirely
    cfg, _params, prec, roll = setup
    eng = _mk_engine(setup, max_slots=1, kv_budget_bytes=1)
    fe = ServingFrontend([eng])
    fe.submit(_prompt(0, 10), max_new=8, rid=0)
    rep = fe.run(max_steps=50)
    assert rep.stalled
    assert len(rep.outputs) == 1                 # accounted, not lost
    assert rep.outputs[0].output.finish_reason == FINISH_ABORT
    assert rep.aborted == 1


def test_engine_cancel_frees_blocks_queue_and_slots(setup):
    eng = _mk_engine(setup)
    eng.submit(_prompt(0, 6), max_new=6, rid=0)
    assert eng.cancel(0)                         # still queued
    assert not eng.cancel(0)                     # idempotent-false
    eng.submit(_prompt(1, 6), max_new=6, rid=1)
    for _ in range(2):
        eng.step()                               # admitted into a slot
    assert any(r is not None and r.rid == 1 for r in eng.slot_req)
    assert eng.cancel(1)
    assert eng.block_mgr.blocks_in_use == 0
    assert all(r is None for r in eng.slot_req)
    rep = eng.run(max_steps=50)
    assert len(rep.completed) == 0 and not eng.queue


def test_host_copy_fault_degrades_to_drop(setup):
    from repro.serving import kv_bytes_per_token, request_state_bytes
    cfg, _params, prec, roll = setup
    per = kv_bytes_per_token(cfg, prec)
    budget = per * 4 * 7 + 2 * request_state_bytes(cfg, prec)

    def serve(faults):
        eng = _mk_engine(setup, max_slots=2, kv_budget_bytes=budget,
                         host_kv_blocks=6, faults=faults)
        toks = {}
        for wave in range(2):
            for j in range(2):
                eng.submit(_prompt(10 * wave + j, 10), max_new=4,
                           rid=2 * wave + j)
            rep = eng.run(max_steps=300)
            assert not rep.stalled
            toks.update({r.rid: list(map(int, r.generated))
                         for r in rep.completed})
        return eng, toks

    eng0, base = serve(None)
    assert eng0.block_mgr.cache_demotions >= 1   # the trace demotes
    inj = FaultInjector(FaultPlan(host_copies=(HostCopyFault(0, 0),)))
    eng1, got = serve(inj)
    assert inj.injected["host_copy_failures"] == 1
    assert eng1.block_mgr.host_copy_faults == 1
    assert got == base                           # never corrupts


# ---------------------------------------------------------------------------
# property: random fault schedules never lose, duplicate, or corrupt
# ---------------------------------------------------------------------------

def test_random_crash_schedules_property(setup):
    hyp = pytest.importorskip("hypothesis")
    st = hyp.strategies
    prompts = [_prompt(s, 5 + 2 * (s % 3)) for s in range(4)]

    oracle_cache = {}

    def oracle(trace_key):
        # fault-free oracle per (replicas, request-set); greedy decode
        # makes it placement-invariant, so one fleet layout suffices
        if trace_key not in oracle_cache:
            replicas, reqs = trace_key
            fe = _mk_fleet(setup, replicas=replicas, max_slots=2)
            for rid, (pi, max_new) in enumerate(reqs):
                fe.submit(prompts[pi], max_new=max_new, rid=rid)
            finals = _run_collect(fe)
            oracle_cache[trace_key] = {
                rid: (tuple(o.output.token_ids),
                      tuple(o.output.versions),
                      o.output.finish_reason)
                for rid, o in finals.items()}
        return oracle_cache[trace_key]

    @hyp.settings(deadline=None, max_examples=12)
    @hyp.given(
        reqs=st.lists(st.tuples(st.integers(0, 3),     # prompt index
                                st.integers(3, 6)),    # max_new
                      min_size=2, max_size=4),
        replicas=st.integers(2, 3),
        plan_seed=st.integers(0, 10_000),
        n_crashes=st.integers(1, 2),
    )
    def run(reqs, replicas, plan_seed, n_crashes):
        # crash-only chaos (no pushes): full bit-exactness vs the
        # fault-free oracle is the contract (greedy + forced-prefix
        # replay under one weight version)
        plan = FaultPlan.random(plan_seed, replicas=replicas,
                                max_step=12, n_crashes=n_crashes,
                                down_steps=2)
        inj = FaultInjector(plan)
        fe = _mk_fleet(setup, replicas=replicas, max_slots=2, faults=inj)
        for rid, (pi, max_new) in enumerate(reqs):
            fe.submit(prompts[pi], max_new=max_new, rid=rid)
        finals = _run_collect(fe)
        got = {rid: (tuple(o.output.token_ids),
                     tuple(o.output.versions),
                     o.output.finish_reason)
               for rid, o in finals.items()}
        want = oracle(( replicas, tuple(reqs)))
        assert sorted(got) == sorted(want)       # no request lost
        for rid, (toks, vers, reason) in got.items():
            wt, wv, wr = want[rid]
            assert toks == wt                    # bit-exact, no dup
            assert vers == wv                    # exact attribution
            assert reason == wr

    run()
