"""Two-tier allocator tests: cross-tier move bookkeeping (demote /
promote / promote_hits), the evictor's demote-before-drop path, the
live-demotion squeeze of the host cache reservation, and a hypothesis
property drawing per-tier capacities — including ``host_blocks=0``,
which must degenerate to the single-tier drop-on-evict allocator —
over random arrival/policy/admission traces, asserting the tier
invariants (every id in exactly one tier, refcounts exactly match
table ownership, host storage conserved) on top of the scheduler ones
(no request lost, budget never exceeded, completions bit-exact vs the
no-preemption oracle)."""
from collections import Counter

import jax
import numpy as np
import pytest

from repro.configs import tiny_serving_config as _cfg
from repro.core import BF16_ROLLOUT
from repro.data import tasks
from repro.models import init_params
from repro.serving import (
    EVICTION_POLICIES,
    BlockManager,
    ServingEngine,
    kv_bytes_per_token,
    request_state_bytes,
)
from repro.serving.block_manager import DEVICE_TIER, HOST_TIER

jax.config.update("jax_platform_name", "cpu")

_prompt = tasks.random_prompt


def _bm(num_blocks=4, host_blocks=4, block_size=4):
    """Bookkeeping-only manager with recording host callbacks."""
    bm = BlockManager(num_blocks=num_blocks, block_size=block_size,
                      host_blocks=host_blocks)
    copies, drops = [], []
    bm.set_host_callbacks(demote_copy=lambda d, h: copies.append((d, h)),
                          host_drop=drops.append)
    return bm, copies, drops


def _toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(4, 19, size=n).astype(np.int32)


# ---------------------------------------------------------------------------
# cross-tier moves: demote / promote / promote_hits
# ---------------------------------------------------------------------------

def test_demote_promote_round_trip():
    bm, _, drops = _bm()
    bm.allocate(0, 3)
    moves = bm.demote(0, 10)                  # 10 tokens -> 3 valid blocks
    assert len(moves) == 3
    assert bm.is_swapped(0) and bm.swapped_tokens(0) == 10
    table = bm.blocks_of(0)
    assert [bm.tier(b) for b in table] == [HOST_TIER] * 3
    assert [h for _, h in moves] == table     # plan order = table order
    assert bm.blocks_in_use == 0 and bm.num_host_live == 3
    back, n = bm.promote(0, shared_ids=[])
    assert n == 3 and [h for h, _ in back] == table
    assert not bm.is_swapped(0)
    assert [bm.tier(b) for b in bm.blocks_of(0)] == [DEVICE_TIER] * 3
    assert bm.num_host_live == 0
    # promote hands storage ownership to the engine's copy loop: no drop
    assert drops == []
    assert (bm.demoted_blocks, bm.promoted_blocks) == (3, 3)


def test_demote_trims_to_valid_tokens():
    """Blocks past the valid count (speculative growth) are released
    without a host copy."""
    bm, _, _ = _bm()
    bm.allocate(0, 3)
    moves = bm.demote(0, 5)                   # 5 tokens -> 2 valid blocks
    assert len(moves) == 2 and len(bm.blocks_of(0)) == 2
    assert bm.num_host_live == 2              # only the valid blocks crossed
    assert bm.num_free_blocks == bm.num_blocks    # device side fully free


def test_promote_shared_head_drops_superseded_host_copies():
    """A swapped-out prefix whose group is still device-resident restores
    for free: the index hit heads the table and the host copies die."""
    bm, _, drops = _bm(num_blocks=6)
    toks = _toks(8)
    bm.allocate(0, 2)
    bm.register_prefix(0, toks)
    bm.acquire(1, bm.blocks_of(0))            # the sharer keeps them live
    shared = bm.blocks_of(1)
    moves = bm.demote(0, 8)
    assert len(moves) == 2                    # sharer may die first: copy all
    hosts = [h for _, h in moves]
    back, n = bm.promote(0, shared_ids=bm.lookup_prefix(toks))
    assert (back, n) == ([], 0)
    assert bm.blocks_of(0) == shared
    assert bm.refcount(shared[0]) == 2
    assert sorted(drops) == sorted(hosts)     # superseded copies freed
    assert bm.num_host_live == 0


def test_evictor_demotes_before_drop_and_revives_by_copy_in():
    bm, copies, _ = _bm(num_blocks=4, host_blocks=4)
    toks = _toks(8)
    bm.allocate(0, 2)
    bm.register_prefix(0, toks)
    bm.free(0)
    assert bm.num_cached_blocks == 2
    dev_hits = bm.lookup_prefix(toks)
    bm.allocate(1, 4)                         # pool-sized: evicts the cache
    assert bm.cache_demotions == 2
    assert [d for d, _ in copies] == dev_hits # content copied out, in order
    hits = bm.lookup_prefix(toks)             # ...still a prefix hit
    assert [bm.tier(b) for b in hits] == [HOST_TIER] * 2
    assert bm.num_host_cached == 2
    bm.free(1)
    table, moves, n = bm.promote_hits(2, hits)
    assert n == 2 and [h for h, _ in moves] == hits
    assert table == bm.blocks_of(2)
    assert [bm.tier(b) for b in table] == [DEVICE_TIER] * 2
    # the index re-pointed across tiers: the revived run hits on device
    assert bm.lookup_prefix(toks) == table
    assert bm.num_host_cached == 0


def test_host_blocks_zero_degenerates_to_drop_on_evict():
    bm, copies, drops = _bm(num_blocks=4, host_blocks=0)
    toks = _toks(8)
    bm.allocate(0, 2)
    bm.register_prefix(0, toks)
    bm.free(0)
    bm.allocate(1, 4)
    assert bm.lookup_prefix(toks) == []       # the entries died
    assert bm.cache_demotions == 0 and bm.num_host_cached == 0
    assert copies == [] and drops == []


def test_live_demotion_squeezes_the_host_cache():
    """Swap-out always succeeds: live host blocks overcommit the
    reservation and the oldest cached entries are dropped to make room."""
    bm, _, drops = _bm(num_blocks=6, host_blocks=2)
    toks = _toks(8)
    bm.allocate(0, 2)
    bm.register_prefix(0, toks)
    bm.free(0)
    bm.allocate(1, 6)                         # evict both -> host cache full
    assert bm.num_host_cached == 2
    cached = bm.lookup_prefix(toks)
    moves = bm.demote(1, 24)                  # 6 live blocks > reservation
    assert len(moves) == 6 and bm.num_host_live == 6
    assert bm.num_host_cached == 0            # cache squeezed out entirely
    assert bm.host_cache_drops == 2 and sorted(drops) == sorted(cached)
    assert bm.lookup_prefix(toks) == []


def test_acquire_rejects_host_tier_ids():
    bm, _, _ = _bm(num_blocks=4, host_blocks=4)
    toks = _toks(8)
    bm.allocate(0, 2)
    bm.register_prefix(0, toks)
    bm.free(0)
    bm.allocate(1, 4)
    hits = bm.lookup_prefix(toks)
    with pytest.raises(ValueError, match="promote_hits"):
        bm.acquire(2, hits)


# ---------------------------------------------------------------------------
# hypothesis property: random traces over drawn per-tier capacities
# ---------------------------------------------------------------------------

_ORACLE_CACHE = {}


def _oracle_tokens(cfg, params, prompt, max_new):
    """No-preemption single-request reference run (greedy decode depends
    only on the prompt; the jnp chunked-prefill path is bit-exact vs
    one-shot, so tiering/chunking must never change tokens)."""
    key = (prompt.tobytes(), max_new)
    if key not in _ORACLE_CACHE:
        eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=1,
                            max_seq_len=32)
        eng.submit(prompt, max_new=max_new, rid=0)
        rep = eng.run(max_steps=200)
        assert len(rep.completed) == 1
        _ORACLE_CACHE[key] = list(rep.completed[0].generated)
    return _ORACLE_CACHE[key]


def _assert_tier_invariants(eng):
    """The allocator/engine cross-tier state is exactly consistent."""
    mgr = eng.block_mgr
    # refcounts are exactly the ownership multiset (both tiers)
    owned = Counter(b for t in mgr._owned.values() for b in t)
    assert dict(owned) == mgr._refcount
    # the device pool partitions into free / cached / refcounted rows
    dev_owned = {b for b in owned if mgr.tier(b) == DEVICE_TIER}
    free, cached = set(mgr._free), set(mgr._cached)
    assert len(mgr._free) == len(free)
    assert not (free & cached) and not (free & dev_owned) \
        and not (cached & dev_owned)
    assert len(free) + len(cached) + len(dev_owned) == mgr.num_blocks
    # host tier: live count matches ownership; the cache never exceeds
    # its live-squeezed reservation
    host_owned = [b for b in owned if mgr.tier(b) == HOST_TIER]
    assert len(host_owned) == mgr.num_host_live
    assert mgr.num_host_cached <= max(mgr.host_blocks - mgr.num_host_live, 0)
    # a request is swapped iff its table lives on the host tier
    for rid, table in mgr._owned.items():
        tiers = {mgr.tier(b) for b in table}
        assert tiers <= ({HOST_TIER} if mgr.is_swapped(rid)
                         else {DEVICE_TIER})
    # engine host storage is conserved: exactly one array set per live or
    # cached host block, none leaked for dead ids
    assert set(eng.host_pool) == set(host_owned) | set(mgr._host_cached)


def test_tiered_invariants_random_traces():
    hyp = pytest.importorskip("hypothesis")
    st = hyp.strategies
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    canonical = [_prompt(s, 4 + 2 * s) for s in range(4)]   # lens 4..10

    @hyp.settings(deadline=None, max_examples=8)
    @hyp.given(
        reqs=st.lists(
            st.tuples(st.integers(0, 3),      # canonical prompt index
                      st.integers(2, 5),      # max_new
                      st.integers(0, 5)),     # arrival step
            min_size=1, max_size=5),
        policy=st.sampled_from(sorted(EVICTION_POLICIES)),
        admission=st.sampled_from(["reserve", "ondemand"]),
        chunk=st.sampled_from([None, 3]),
        budget_blocks=st.integers(5, 10),     # device-tier capacity
        host_blocks=st.sampled_from([0, 2, 6]),   # host-tier capacity
    )
    def run(reqs, policy, admission, chunk, budget_blocks, host_blocks):
        per = kv_bytes_per_token(cfg, BF16_ROLLOUT)
        budget = per * 4 * budget_blocks + \
            3 * request_state_bytes(cfg, BF16_ROLLOUT)
        eng = ServingEngine(
            params, cfg, BF16_ROLLOUT, max_slots=3, max_seq_len=32,
            kv_budget_bytes=budget, admission=admission, eviction=policy,
            prefill_chunk=chunk, host_kv_blocks=host_blocks)
        if host_blocks == 0:
            # the evictor degenerates to seed drop-on-evict (live
            # swap-out demotions are reservation-exempt and still run)
            assert eng.block_mgr.host_blocks == 0
        submitted = {}
        by_arrival = sorted(enumerate(reqs), key=lambda kv: kv[1][2])
        idx = 0
        for tick in range(400):
            while idx < len(by_arrival) and by_arrival[idx][1][2] <= tick:
                rid, (pi, max_new, _) = by_arrival[idx]
                eng.submit(canonical[pi], max_new=max_new, rid=rid)
                submitted[rid] = (pi, max_new)
                idx += 1
            decision = eng.step()
            assert eng.block_mgr.blocks_in_use <= eng._effective_blocks
            _assert_tier_invariants(eng)
            queued = [r.rid for r in eng.queue]
            running = [r.rid for r in eng.slot_req if r is not None]
            done = [r.rid for r in eng.done]
            everywhere = queued + running + done
            assert sorted(everywhere) == sorted(set(everywhere))
            assert set(everywhere) == set(submitted)
            if idx == len(by_arrival) and decision.is_empty:
                break
        assert len(eng.done) == len(submitted)
        for r in eng.done:
            pi, max_new = submitted[r.rid]
            assert list(r.generated) == _oracle_tokens(
                cfg, params, canonical[pi], max_new), \
                f"rid {r.rid} diverged (policy={policy}, chunk={chunk}, " \
                f"admission={admission}, host_blocks={host_blocks})"
        # host_blocks=0 disables the evictor's demote-to-host cache (seed
        # drop-on-evict); LIVE swap-out demotions are reservation-exempt
        # and may still mint host blocks, so demoted_blocks stays free.
        if host_blocks == 0:
            assert eng.block_mgr.cache_demotions == 0
            assert eng.block_mgr.num_host_cached == 0
        # end state: no device blocks held, no live host blocks, and the
        # only host storage left is the (bounded) demoted prefix cache
        assert eng.block_mgr.blocks_in_use == 0
        assert eng.block_mgr.num_host_live == 0
        assert set(eng.host_pool) == set(eng.block_mgr._host_cached)

    run()


def test_same_plan_swap_out_readmit_conserves_host_storage():
    """Regression: a GRPO trio under a tight device budget produces plans
    where a victim is swapped out and re-admitted in the SAME step.  The
    plan-time promote retires the shared-head host ids (device prefix
    hits supersede them) before the SwapOut's copies materialize at
    execute time — the engine must not write storage for the dead ids,
    or `host_pool` leaks them forever."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    prompt = _prompt(3, 10)
    per = kv_bytes_per_token(cfg, BF16_ROLLOUT)
    budget = per * 4 * 7 + 3 * request_state_bytes(cfg, BF16_ROLLOUT)
    eng = ServingEngine(
        params, cfg, BF16_ROLLOUT, max_slots=3, max_seq_len=32,
        kv_budget_bytes=budget, admission="ondemand",
        eviction="private-blocks", prefill_chunk=3, host_kv_blocks=2)
    for rid in range(3):
        eng.submit(prompt, max_new=5, rid=rid)
    saw_same_plan = False
    for _ in range(400):
        decision = eng.step()
        kinds = [type(a).__name__ for a in decision.actions]
        if "SwapOut" in kinds and "Admit" in kinds:
            saw_same_plan = True
        _assert_tier_invariants(eng)
        if decision.is_empty and not eng.queue \
                and all(r is None for r in eng.slot_req):
            break
    assert saw_same_plan, "trace no longer exercises the hazard"
    assert len(eng.done) == 3
    oracle = _oracle_tokens(cfg, params, prompt, 5)
    for r in eng.done:
        assert list(r.generated) == oracle
    # end state: only the (bounded) demoted prefix cache may hold storage
    assert set(eng.host_pool) == set(eng.block_mgr._host_cached)
