"""Per-architecture smoke tests (assignment requirement).

Each assigned arch (+ the paper's two) instantiates a REDUCED same-family
config and runs, on CPU:
  * one training forward + backward step — asserts output shapes + no NaNs
  * prefill + a few decode steps in BF16 and FP8 rollout modes
  * (decoder families) consistency: decode logits == teacher-forced logits
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, get_config
from repro.core import BF16_ROLLOUT, FULL_FP8_ROLLOUT
from repro.core.fp8_params import quantize_params
from repro.models import (
    decode_step,
    forward_train,
    init_cache,
    init_params,
    prefill,
)

jax.config.update("jax_platform_name", "cpu")

ARCHS = sorted(REGISTRY)
B, T = 2, 16


def _inputs(cfg, b=B, t=T, key=0):
    ks = jax.random.split(jax.random.key(key), 3)
    inp = {"tokens": jax.random.randint(ks[0], (b, t), 0, cfg.vocab_size)}
    if cfg.frontend == "vision_patches":
        p = max(cfg.frontend_len, 4)
        inp["patches"] = jax.random.normal(ks[1], (p and 4, 4, cfg.d_model),
                                           jnp.bfloat16)
        inp["patches"] = jax.random.normal(ks[1], (b, 4, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        inp["frames"] = jax.random.normal(ks[2], (b, 8, cfg.d_model), jnp.bfloat16)
        inp["src_lengths"] = jnp.array([8, 5][:b])
    return inp


@pytest.fixture(scope="module")
def models():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            params = init_params(cfg, jax.random.key(42))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_forward_shapes_no_nan(models, arch):
    cfg, params = models(arch)
    inp = _inputs(cfg)
    logits, aux = forward_train(params, inp, cfg)
    t_total = T + (4 if cfg.frontend == "vision_patches" else 0)
    assert logits.shape == (B, t_total, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_grads_finite(models, arch):
    cfg, params = models(arch)
    inp = _inputs(cfg)

    def loss_fn(p):
        logits, aux = forward_train(p, inp, cfg)
        tok = inp["tokens"]
        pref = aux.get("prefix_len", 0)
        lp = jax.nn.log_softmax(logits[:, pref:][:, :-1].astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(lp, tok[:, 1:, None], -1))

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat)
    # embedding must receive gradient
    assert float(jnp.abs(grads["emb"]).max()) > 0


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("mode", ["bf16", "fp8"])
def test_prefill_decode_no_nan(models, arch, mode):
    cfg, params = models(arch)
    precision = BF16_ROLLOUT if mode == "bf16" else FULL_FP8_ROLLOUT
    p_roll = params if mode == "bf16" else quantize_params(params, precision)
    inp = _inputs(cfg)
    inp["lengths"] = jnp.array([T, T - 3][:B])
    max_len = T + 8
    src = inp["frames"].shape[1] if cfg.is_encdec else 0
    cache = init_cache(cfg, B, max_len, precision, src_len=src)
    logits, cache = prefill(p_roll, inp, cache, cfg, precision)
    assert logits.shape == (B, cfg.vocab_size)
    assert not np.any(np.isnan(np.asarray(logits)))
    tok = jnp.argmax(logits, -1)
    for _ in range(3):
        logits, cache, _ = decode_step(p_roll, tok, cache, cfg, precision)
        assert not np.any(np.isnan(np.asarray(logits)))
        tok = jnp.argmax(logits, -1)
    assert int(cache["lengths"][0]) == (T if cfg.frontend != "vision_patches"
                                        else T + 4) + 3


@pytest.mark.parametrize("arch", ["llama3.2-3b", "mamba2-780m",
                                  "granite-moe-3b-a800m",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_teacher_forcing(models, arch):
    """Greedy decode logits must match the teacher-forced forward on the
    same token sequence.

    Run in float32 to verify *algorithmic* equivalence of the incremental
    (cache/recurrence) path and the full-sequence (chunked) path.  In bf16
    the two paths differ by accumulation order — that residual divergence is
    precisely the paper's train-inference mismatch premise and is measured
    (not asserted away) in the mismatch-KL tests."""
    cfg, _ = models(arch)
    params = init_params(cfg, jax.random.key(42), dtype=jnp.float32)
    precision = BF16_ROLLOUT
    t0 = 8
    inp = {"tokens": jax.random.randint(jax.random.key(7), (1, t0), 0,
                                        cfg.vocab_size),
           "lengths": jnp.array([t0])}
    cache = init_cache(cfg, 1, t0 + 4, precision, dtype=jnp.float32)
    logits_p, cache = prefill(params, inp, cache, cfg, precision)
    toks = [int(jnp.argmax(logits_p, -1)[0])]
    dec_logits = [logits_p]
    for _ in range(2):
        lg, cache, _ = decode_step(params, jnp.array(toks[-1:]), cache, cfg,
                                   precision)
        dec_logits.append(lg)
        toks.append(int(jnp.argmax(lg, -1)[0]))
    # teacher-forced pass over prompt + generated tokens
    full = jnp.concatenate([inp["tokens"], jnp.array([toks[:2]])], axis=1)
    tf_logits, _ = forward_train(params, {"tokens": full}, cfg, precision,
                                 remat=False)
    for i, dl in enumerate(dec_logits):
        ref = np.asarray(tf_logits[0, t0 - 1 + i], np.float32)
        got = np.asarray(dl[0], np.float32)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_configs_exact_dims():
    """Spot-check the assigned table dims survive into the configs."""
    c = get_config("mistral-large-123b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == (88, 12288, 96, 8, 28672, 32768)
    c = get_config("jamba-1.5-large-398b")
    assert (c.n_experts, c.top_k, c.attn_period) == (16, 2, 8)
    c = get_config("granite-moe-3b-a800m")
    assert (c.n_experts, c.top_k) == (40, 8)
    c = get_config("mamba2-780m")
    assert c.attention_free and c.ssm_state == 128
    c = get_config("seamless-m4t-medium")
    assert c.is_encdec and c.vocab_size == 256206


def test_param_counts_plausible():
    """Analytic N within the advertised ballpark (loose: naming sizes are
    nominal marketing numbers)."""
    expect = {
        "mistral-large-123b": (100e9, 140e9),
        "grok-1-314b": (250e9, 360e9),
        "jamba-1.5-large-398b": (300e9, 480e9),
        "mamba2-780m": (0.4e9, 1.2e9),
        "qwen3-8b": (6e9, 10e9),
        "starcoder2-15b": (12e9, 18e9),
    }
    for name, (lo, hi) in expect.items():
        n = get_config(name).param_count()
        assert lo < n < hi, (name, n)


def test_long_500k_assignment():
    runs = {n for n, c in REGISTRY.items()
            if any(s.name == "long_500k" for s in c.shapes())}
    assert runs == {"mamba2-780m", "jamba-1.5-large-398b"}
