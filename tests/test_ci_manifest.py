"""CI manifest drift guards + the bench-trend baseline harness.

Two failure modes this file exists to catch:

1. **Silent gate drop.**  A benchmark grows a ``--check`` gate (it goes
   through ``bench_cli``) but nobody wires it into the bench-smoke job —
   so the gate exists and never runs.  The manifest test parses
   ``.github/workflows/ci.yml`` and asserts every gated benchmark in
   ``benchmarks.run.MODULES`` has a bench-smoke step that passes
   ``--check`` and writes a JSON artifact the upload glob covers.

2. **Silent trend drift.**  The baseline harness itself regresses — a
   regressed metric reads green, or a vanished metric reads ok.  The
   injected-regression tests feed ``compare_metrics`` doctored numbers
   and assert red, then the shipped numbers and assert green.
"""
from __future__ import annotations

import fnmatch
import json
import re
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(REPO))

from benchmarks import common, run  # noqa: E402

yaml = pytest.importorskip("yaml", reason="manifest test parses ci.yml")

CI_YML = REPO / ".github" / "workflows" / "ci.yml"


def _workflow() -> dict:
    with open(CI_YML) as f:
        return yaml.safe_load(f)


def _bench_smoke_steps() -> list:
    return _workflow()["jobs"]["bench-smoke"]["steps"]


def _gated_benchmarks() -> list:
    """MODULES entries whose source goes through bench_cli — i.e. the
    benchmarks that HAVE a --check gate to wire up."""
    gated = []
    for name, _desc in run.MODULES:
        src = (REPO / "benchmarks" / f"{name}.py").read_text()
        if "bench_cli(" in src:
            gated.append(name)
    return gated


# ---------------------------------------------------------------------------
# 1. manifest: every gate runs in CI, every artifact is uploaded
# ---------------------------------------------------------------------------

def test_every_gated_benchmark_has_a_checked_smoke_step():
    gated = _gated_benchmarks()
    assert len(gated) >= 11, f"gate inventory shrank: {gated}"
    assert "fault_tolerance" in gated, (
        "the fleet chaos gate (failover exactly-once, atomic pushes, "
        "zero-perturbation injector) must stay wired into CI")
    assert "tiered_kv" in gated, (
        "the tiered-KV revival gate left the registry — the two-tier "
        "allocator's cross-tier win is no longer asserted in CI")
    runs = [s.get("run", "") for s in _bench_smoke_steps() if "run" in s]
    for name in gated:
        matching = [r for r in runs if f"benchmarks/{name}.py" in r]
        assert matching, (
            f"benchmarks/{name}.py is gated (uses bench_cli) but the "
            "bench-smoke job never runs it — its --check gate is dead")
        assert any("--check" in r for r in matching), (
            f"bench-smoke runs benchmarks/{name}.py without --check: "
            "the invariants are never asserted")


def _upload_globs() -> list:
    uploads = [s for s in _bench_smoke_steps()
               if "upload-artifact" in str(s.get("uses", ""))]
    assert uploads, "bench-smoke lost its artifact upload step"
    # `path:` may be a single glob or a `|` block with one glob per line
    return [g.strip() for g in uploads[-1]["with"]["path"].splitlines()
            if g.strip()]


def test_every_smoke_json_is_covered_by_the_artifact_glob():
    globs = _upload_globs()
    for step in _bench_smoke_steps():
        for jpath in re.findall(r"--json\s+(\S+)", step.get("run", "")):
            assert any(fnmatch.fnmatch(jpath, g) for g in globs), (
                f"{jpath} written by '{step.get('name')}' is not covered "
                f"by the upload globs {globs!r} — the artifact vanishes")


def test_trace_sample_artifact_is_uploaded_but_not_trended():
    """benchmarks/observability.py drops a Perfetto-loadable
    obs-sample.trace.json next to its --json output.  It must ride the
    artifact upload for humans, but must NOT match the bench-*.json glob
    the trend step aggregates — it is a chrome trace, not a metrics run,
    and feeding it to benchmarks.common would red the trend on MISSING."""
    globs = _upload_globs()
    assert any(fnmatch.fnmatch("obs-sample.trace.json", g) for g in globs), (
        f"obs-sample.trace.json not covered by upload globs {globs!r}")
    trend = [s.get("run", "") for s in _bench_smoke_steps()
             if "benchmarks.common" in s.get("run", "")]
    trend_glob = re.search(r"(bench-\*\.\w+)", trend[0]).group(1)
    assert not fnmatch.fnmatch("obs-sample.trace.json", trend_glob), (
        "the trace artifact matches the trend glob — benchmarks.common "
        "would try to parse a chrome trace as a metrics artifact")


def test_bench_trend_step_runs_against_committed_baselines():
    runs = [s.get("run", "") for s in _bench_smoke_steps()]
    trend = [r for r in runs if "benchmarks.common" in r]
    assert trend, "bench-smoke lost the aggregate bench-trend step"
    assert "--baseline benchmarks/baselines.json" in trend[0].replace(
        "\n", " ")


def test_baselines_cover_every_smoke_artifact():
    """A new gated benchmark must land with a baselines entry in the same
    PR, or the aggregate trend pass goes red on MISSING."""
    with open(REPO / "benchmarks" / "baselines.json") as f:
        baselines = json.load(f)
    for step in _bench_smoke_steps():
        for jpath in re.findall(r"--json\s+(\S+)", step.get("run", "")):
            bench = common.bench_name_from_path(jpath)
            assert bench in baselines, (
                f"bench-smoke writes {jpath} but baselines.json has no "
                f"'{bench}' entry — the trend gate would fail on MISSING")
            assert baselines[bench], f"'{bench}' baseline entry is empty"


def test_lint_format_step_is_blocking():
    steps = _workflow()["jobs"]["lint"]["steps"]
    fmt = [s for s in steps if "format" in str(s.get("run", ""))]
    assert fmt, "lint job lost the ruff format step"
    assert not fmt[0].get("continue-on-error", False), (
        "ruff format went advisory again — formatting drift accumulates")


def test_pytest_matrix_and_hypothesis_profile():
    job = _workflow()["jobs"]["pytest"]
    versions = job["strategy"]["matrix"]["python-version"]
    assert "3.13" in versions, f"3.13 dropped from the matrix: {versions}"
    suite = [s for s in job["steps"]
             if "pytest" in str(s.get("run", ""))][0]
    assert suite.get("env", {}).get("HYPOTHESIS_PROFILE") == "ci", (
        "the pytest job must pin HYPOTHESIS_PROFILE=ci so property-test "
        "failures reproduce from the printed blob")


# ---------------------------------------------------------------------------
# 2. the baseline harness itself: red on regression, green on shipped
# ---------------------------------------------------------------------------

_BASELINES = {"demo": {
    "scaling.x": {"value": 2.0, "tol": 0.15, "direction": "higher"},
    "lat.p50": {"value": 10.0, "tol": 0.20, "direction": "lower"},
}}


def test_injected_regression_reads_red():
    rows = common.compare_metrics(
        "demo", {"scaling": {"x": 1.2}, "lat": {"p50": 10.0}}, _BASELINES)
    by = {r["metric"]: r["status"] for r in rows}
    assert by["scaling.x"] == "REGRESSED"
    assert by["lat.p50"] == "ok"


def test_lower_is_better_direction():
    rows = common.compare_metrics(
        "demo", {"scaling": {"x": 2.0}, "lat": {"p50": 14.0}}, _BASELINES)
    by = {r["metric"]: r["status"] for r in rows}
    assert by["lat.p50"] == "REGRESSED"      # 10 -> 14 beyond 20% band
    rows = common.compare_metrics(
        "demo", {"scaling": {"x": 2.0}, "lat": {"p50": 7.0}}, _BASELINES)
    assert {r["metric"]: r["status"] for r in rows}["lat.p50"] == "improved"


def test_within_band_and_improved_read_green():
    rows = common.compare_metrics(
        "demo", {"scaling": {"x": 2.4}, "lat": {"p50": 10.5}}, _BASELINES)
    statuses = {r["status"] for r in rows}
    assert statuses <= {"ok", "improved"}, rows


def test_missing_metric_and_missing_bench_read_red():
    rows = common.compare_metrics("demo", {"lat": {"p50": 10.0}}, _BASELINES)
    assert {r["metric"]: r["status"] for r in rows}["scaling.x"] == "MISSING"
    rows = common.compare_metrics("unknown_bench", {}, _BASELINES)
    assert rows[0]["status"] == "MISSING"


def test_non_numeric_metric_is_missing_not_green():
    rows = common.compare_metrics(
        "demo", {"scaling": {"x": True}, "lat": {"p50": "fast"}}, _BASELINES)
    assert all(r["status"] == "MISSING" for r in rows), rows


def test_aggregate_cli_exit_codes(tmp_path, monkeypatch):
    base = tmp_path / "baselines.json"
    base.write_text(json.dumps(_BASELINES))
    good = tmp_path / "bench-demo.json"
    good.write_text(json.dumps({"scaling": {"x": 2.1}, "lat": {"p50": 9.0}}))
    summary = tmp_path / "summary.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(summary))
    assert common.main(["--baseline", str(base), str(good)]) == 0
    assert "benchmark trend" in summary.read_text().lower()

    bad = tmp_path / "bench-demo.json"
    bad.write_text(json.dumps({"scaling": {"x": 1.0}, "lat": {"p50": 9.0}}))
    assert common.main(["--baseline", str(base), str(bad)]) == 1


def test_artifact_name_normalization():
    assert common.bench_name_from_path("bench-kernel-hotpath.json") \
        == "kernel_hotpath"
    assert common.bench_name_from_path(
        "/tmp/x/bench-live_update.json") == "live_update"


def test_shipped_baselines_match_shipped_artifacts_shape():
    """Every committed baseline metric must use a real dotted path shape
    (non-empty, no accidental leading/trailing dots)."""
    with open(REPO / "benchmarks" / "baselines.json") as f:
        baselines = json.load(f)
    assert baselines, "baselines.json is empty"
    for bench, spec in baselines.items():
        for metric, band in spec.items():
            assert metric.strip(".") == metric and metric, (bench, metric)
            assert "value" in band, (bench, metric)
            assert band.get("direction", "higher") in ("higher", "lower")
            assert 0 < float(band.get("tol", 0.1)) < 1, (bench, metric)
