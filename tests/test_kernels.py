"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle.

Covers the three FP8-RL kernels with hypothesis shape/dtype sweeps plus
directed edge cases (padding, GQA group sizes, masked lengths).
"""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="install requirements-dev.txt for property tests")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import E4M3, E5M2, ScaleFormat
from repro.core import quant as cq
from repro.kernels import fp8_gemm as gemm_mod
from repro.kernels import fp8_kv_attention as attn_mod
from repro.kernels import fp8_quant as quant_mod
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# fp8_quant
# ---------------------------------------------------------------------------

def test_quant_act_kernel_matches_ref():
    x = jax.random.normal(jax.random.key(0), (64, 384), jnp.bfloat16) * 3
    qk, sk = quant_mod.quantize_activation_kernel(x, bm=32, interpret=True)
    qr, sr = ref.quantize_activation_ref(x)
    np.testing.assert_array_equal(np.asarray(qk, np.float32), np.asarray(qr, np.float32))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_quant_weight_kernel_matches_ref():
    w = jax.random.normal(jax.random.key(1), (256, 384), jnp.float32) * 0.1
    qk, sk = quant_mod.quantize_weight_kernel(w, interpret=True)
    qr, sr = ref.quantize_weight_ref(w)
    np.testing.assert_array_equal(np.asarray(qk, np.float32), np.asarray(qr, np.float32))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_quant_kernel_matches_core_quant():
    """Kernel path and the core library path implement the same spec."""
    x = jax.random.normal(jax.random.key(2), (32, 256), jnp.float32)
    qt_kernel = ops.quantize_activation(x)
    qt_core = cq.quantize_activation(x)
    np.testing.assert_array_equal(
        np.asarray(qt_kernel.data, np.float32), np.asarray(qt_core.data, np.float32)
    )
    np.testing.assert_allclose(
        np.asarray(qt_kernel.scales), np.asarray(qt_core.scales), rtol=1e-6
    )


@hypothesis.settings(max_examples=10, deadline=None)
@hypothesis.given(
    m=st.sampled_from([8, 32, 96]),
    kb=st.integers(1, 4),
    fp8=st.sampled_from([E4M3, E5M2]),
    fmt=st.sampled_from([ScaleFormat.FP32, ScaleFormat.UE8M0]),
    mag=st.floats(0.01, 100.0),
)
def test_property_quant_act_sweep(m, kb, fp8, fmt, mag):
    x = jax.random.normal(jax.random.key(m * kb), (m, kb * 128), jnp.float32) * mag
    qk, sk = quant_mod.quantize_activation_kernel(
        x, fp8_dtype=fp8, scale_format=fmt, bm=8, interpret=True)
    qr, sr = ref.quantize_activation_ref(x, fp8_dtype=fp8, scale_format=fmt)
    np.testing.assert_array_equal(np.asarray(qk, np.float32), np.asarray(qr, np.float32))
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-6)


def test_quant_ops_padding_nonmultiple():
    """ops wrapper: K not a multiple of 128, odd leading dims."""
    x = jax.random.normal(jax.random.key(3), (3, 5, 200), jnp.float32)
    qt = ops.quantize_activation(x)
    assert qt.data.shape == (3, 5, 200)
    assert qt.scales.shape == (3, 5, 2)
    deq = cq.dequantize(qt, jnp.float32)
    rel = np.abs(np.asarray(deq) - np.asarray(x)) / np.maximum(np.abs(np.asarray(x)), 1e-6)
    assert np.percentile(rel, 99) < 0.08


# ---------------------------------------------------------------------------
# fp8_gemm
# ---------------------------------------------------------------------------

def _mk_quantized(key, m, k, n, mag=1.0):
    kx, kw = jax.random.split(jax.random.key(key))
    x = jax.random.normal(kx, (m, k), jnp.float32) * mag
    w = jax.random.normal(kw, (k, n), jnp.float32) * mag
    xq, xs = ref.quantize_activation_ref(x)
    wq, ws = ref.quantize_weight_ref(w)
    return x, w, xq, xs, wq, ws


def test_gemm_kernel_matches_ref_exact():
    """Kernel vs oracle on identical fp8 inputs: same math, tight tolerance."""
    _, _, xq, xs, wq, ws = _mk_quantized(10, 256, 256, 256)
    y_k = gemm_mod.fp8_gemm(xq, wq, xs, ws, bm=128, bn=128, interpret=True)
    y_r = ref.fp8_gemm_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_r, np.float32), rtol=2e-2, atol=1e-3
    )


def test_gemm_close_to_fp32_matmul():
    """End-to-end quantized GEMM approximates the fp32 product (the paper's
    accuracy premise for W8A8)."""
    x, w, xq, xs, wq, ws = _mk_quantized(11, 128, 384, 128)
    y_k = np.asarray(gemm_mod.fp8_gemm(xq, wq, xs, ws, bm=128, bn=128,
                                       interpret=True), np.float32)
    y_f = np.asarray(x @ w)
    denom = np.abs(y_f).mean() + 1e-6
    assert np.abs(y_k - y_f).mean() / denom < 0.05


@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(
    mb=st.integers(1, 2), kb=st.integers(1, 3), nb=st.integers(1, 2),
    bm=st.sampled_from([128, 256]), bn=st.sampled_from([128, 256]),
    mag=st.floats(0.05, 20.0),
)
def test_property_gemm_sweep(mb, kb, nb, bm, bn, mag):
    m, k, n = mb * 256, kb * 128, nb * 256
    _, _, xq, xs, wq, ws = _mk_quantized(mb * 100 + kb * 10 + nb, m, k, n, mag)
    y_k = gemm_mod.fp8_gemm(xq, wq, xs, ws, bm=bm, bn=bn, interpret=True)
    y_r = ref.fp8_gemm_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_r, np.float32),
        rtol=2e-2, atol=1e-3 * mag * mag,
    )


def test_gemm_ops_wrapper_arbitrary_shapes():
    """fp8_matmul pads (M=9, K=200, N=130) correctly."""
    x = jax.random.normal(jax.random.key(12), (9, 200), jnp.float32)
    w = jax.random.normal(jax.random.key(13), (200, 130), jnp.float32)
    y = ops.fp8_matmul(ops.quantize_activation(x), ops.quantize_weight(w))
    assert y.shape == (9, 130)
    y_f = np.asarray(x @ w)
    err = np.abs(np.asarray(y, np.float32) - y_f).mean() / (np.abs(y_f).mean() + 1e-6)
    assert err < 0.06


def test_gemm_ops_batched_input():
    x = jax.random.normal(jax.random.key(14), (2, 3, 128), jnp.float32)
    w = jax.random.normal(jax.random.key(15), (128, 256), jnp.float32)
    y = ops.fp8_matmul(ops.quantize_activation(x), ops.quantize_weight(w))
    assert y.shape == (2, 3, 256)


# ---------------------------------------------------------------------------
# fp8_kv_attention
# ---------------------------------------------------------------------------

def _mk_attn(key, b, kvh, g, d, s, dtype=jnp.float8_e4m3fn):
    ks = jax.random.split(jax.random.key(key), 4)
    q = jax.random.normal(ks[0], (b, kvh, g, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kvh, d), jnp.float32)
    k_scale = jnp.float32(jnp.abs(k).max() / 448.0)
    v_scale = jnp.float32(jnp.abs(v).max() / 448.0)
    kq = cq.quantize_per_tensor(k, k_scale, dtype)
    vq = cq.quantize_per_tensor(v, v_scale, dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
    return q, kq, vq, k_scale, v_scale, lengths


def test_decode_attention_matches_ref():
    q, kq, vq, ks, vs, lengths = _mk_attn(20, b=2, kvh=2, g=4, d=64, s=256)
    out_k = attn_mod.fp8_decode_attention(q, kq, vq, ks, vs, lengths, bs=128,
                                          interpret=True)
    out_r = ref.fp8_decode_attention_ref(q, kq, vq, ks, vs, lengths)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_decode_attention_bf16_kv_path():
    """bf16 KV (no quantization) must also work — dequant is a scale-by-1."""
    b, kvh, g, d, s = 1, 2, 2, 32, 128
    keys = jax.random.split(jax.random.key(21), 3)
    q = jax.random.normal(keys[0], (b, kvh, g, d), jnp.bfloat16)
    k = jax.random.normal(keys[1], (b, s, kvh, d), jnp.bfloat16)
    v = jax.random.normal(keys[2], (b, s, kvh, d), jnp.bfloat16)
    one = jnp.float32(1.0)
    lengths = jnp.array([s])
    out_k = attn_mod.fp8_decode_attention(q, k, v, one, one, lengths, bs=128,
                                          interpret=True)
    out_r = ref.fp8_decode_attention_ref(q, k, v, one, one, lengths)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_decode_attention_length_masking():
    """Tokens past `lengths` must not contribute: poison them with huge values."""
    q, kq, vq, ks, vs, _ = _mk_attn(22, b=1, kvh=1, g=2, d=32, s=256)
    lengths = jnp.array([100])
    vq_poison = vq.at[:, 100:].set(jnp.float32(448).astype(vq.dtype))
    kq_poison = kq.at[:, 100:].set(jnp.float32(448).astype(kq.dtype))
    out_p = attn_mod.fp8_decode_attention(q, kq_poison, vq_poison, ks, vs,
                                          lengths, bs=128, interpret=True)
    out_c = attn_mod.fp8_decode_attention(q, kq, vq, ks, vs, lengths, bs=128,
                                          interpret=True)
    np.testing.assert_array_equal(np.asarray(out_p, np.float32),
                                  np.asarray(out_c, np.float32))


@hypothesis.settings(max_examples=8, deadline=None)
@hypothesis.given(
    b=st.integers(1, 3),
    kvh=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 8]),   # GQA group sizes
    d=st.sampled_from([32, 64, 128]),
    sb=st.integers(1, 3),
)
def test_property_decode_attention_sweep(b, kvh, g, d, sb):
    s = sb * 128
    q, kq, vq, ks, vs, lengths = _mk_attn(b * 1000 + kvh * 100 + g * 10 + sb,
                                          b=b, kvh=kvh, g=g, d=d, s=s)
    out_k = attn_mod.fp8_decode_attention(q, kq, vq, ks, vs, lengths, bs=128,
                                          interpret=True)
    out_r = ref.fp8_decode_attention_ref(q, kq, vq, ks, vs, lengths)
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        rtol=2e-2, atol=2e-2,
    )


def test_decode_attention_ops_padding():
    """ops wrapper pads odd S."""
    q, kq, vq, ks, vs, lengths = _mk_attn(23, b=1, kvh=1, g=2, d=32, s=200)
    out = ops.fp8_decode_attention(q, kq, vq, ks, vs, lengths)
    out_r = ref.fp8_decode_attention_ref(q, kq, vq, ks, vs, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(out_r, np.float32),
        rtol=2e-2, atol=2e-2,
    )
