"""Continuous-batching scheduler tests: chunked prefill, eviction-policy
registry, decision cost accounting, the paged-kernel decode path, and a
hypothesis property over random arrival/length/policy/layer-pattern/
kernel-config/speculation traces (pure attention and attn+ssm hybrid;
jnp fallback, paged decode kernel, and the full decode+prefill kernel
hot path; speculative decoding on or off) asserting the scheduler
invariants (no request lost or duplicated, the block budget is never
exceeded, completed tokens are bit-exact vs a NON-SPECULATIVE
no-preemption oracle running the same numerics path — preemption,
chunking and speculation never change hot-path tokens).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tiny_serving_config as _cfg
from repro.core import BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT
from repro.data import tasks
from repro.models import decode_step, init_cache, init_params, prefill
from repro.rl import sync_policy_weights
from repro.serving import (
    EVICTION_POLICIES,
    KernelConfig,
    ServingEngine,
    SpecConfig,
    StepBudget,
    kv_bytes_per_token,
    request_state_bytes,
)

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def setup():
    cfg = _cfg()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


_prompt = tasks.random_prompt


# ---------------------------------------------------------------------------
# chunked prefill
# ---------------------------------------------------------------------------

def test_long_prompt_serves_via_chunked_prefill(setup):
    """A prompt longer than prompt_pad is rejected by batch-1 admission
    and served end-to-end by chunked prefill."""
    cfg, params = setup
    prompt = _prompt(1, 25)                       # > prompt_pad=16
    legacy = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                           max_seq_len=48)
    with pytest.raises(ValueError, match="prompt_pad"):
        legacy.submit(prompt, max_new=6)

    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=48, prefill_chunk=8)
    eng.submit(prompt, max_new=6, rid=0)
    rep = eng.run(max_steps=100)
    assert len(rep.completed) == 1
    assert len(rep.completed[0].generated) >= 1
    assert rep.prefill_chunks >= 4                # ceil(25/8) + none wasted
    assert eng.block_mgr.blocks_in_use == 0


@pytest.mark.parametrize("precision", [BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT],
                         ids=["bf16", "fp8"])
def test_chunked_prefill_bit_exact_vs_batch1(setup, precision):
    """For prompts both admission modes can serve, chunked prefill must
    decode the exact same tokens as the one-shot batch-1 path.

    This now holds with QUANTIZED KV too (the PR 3 BF16-only caveat is
    gone): the scheduler serves the calibrating prefill as one full-width
    chunk, so the KV-scale amax window — and therefore every quantized
    byte — matches the one-shot path exactly."""
    cfg, params = setup
    prompts = [_prompt(s, int(5 + s % 9)) for s in range(6)]
    outs = {}
    scales = {}
    for mode, kw in (("batch1", {}),
                     ("chunked", dict(prefill_chunk=4,
                                      step_budget=StepBudget(
                                          prefill_tokens=8)))):
        eng = ServingEngine(params, cfg, precision, max_slots=4,
                            max_seq_len=32, **kw)
        for i, p in enumerate(prompts):
            eng.submit(p, max_new=6, rid=i)
        rep = eng.run(max_steps=300)
        assert len(rep.completed) == len(prompts)
        outs[mode] = {r.rid: list(r.generated) for r in rep.completed}
        scales[mode] = np.asarray(eng.cache["slots"]["s0"]["kv"].k_scale)
    assert outs["chunked"] == outs["batch1"]
    np.testing.assert_array_equal(scales["chunked"], scales["batch1"])


def test_chunked_prefill_piggybacks_alongside_decode(setup):
    """With a per-step prefill-token budget, a long prompt streams in
    across steps while an already-admitted request keeps decoding — the
    admission stall of batch-1 prefill is gone."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=48, prefill_chunk=4,
                        step_budget=StepBudget(prefill_tokens=4),
                        eos_id=None)
    eng.submit(_prompt(0, 6), max_new=12, rid=0)
    eng.step()                                    # rid 0 admitted + decoding
    eng.submit(_prompt(1, 20), max_new=4, rid=1)  # 5 chunks to stream
    saw_piggyback = False
    for _ in range(30):
        d = eng.step()
        if d.is_empty:
            break
        if d.prefill_tokens > 0 and 0 in d.decode_slots:
            saw_piggyback = True                  # chunk + decode, one step
    assert saw_piggyback
    assert len(eng.done) == 2


def test_chunk_skip_starts_past_shared_prefix(setup):
    """A second same-prompt request admitted after the first completed its
    prefill skips the shared full blocks outright (prefix-cache compute
    saving, not just memory dedup)."""
    cfg, params = setup
    prompt = _prompt(3, 12)                       # 3 full blocks of 4
    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=32, prefill_chunk=4, eos_id=None)
    eng.submit(prompt, max_new=4, rid=0)
    for _ in range(6):
        eng.step()
    r0 = next(r for r in (eng.done + [x for x in eng.slot_req if x])
              if r.rid == 0)
    assert r0.prefilled == len(prompt)
    chunks_before = eng.stats["prefill_chunks"]
    eng.submit(prompt, max_new=4, rid=1)
    eng.run(max_steps=60)
    assert len(eng.done) == 2
    # rid 1 shares blocks 0..1 and prefills only the tail chunk:
    # chunks used for rid 1 is strictly fewer than a full prefill needs
    assert eng.stats["prefill_chunks"] - chunks_before < 3
    assert eng.stats["prefix_hits"] >= 2


# ---------------------------------------------------------------------------
# eviction policies / decision plumbing
# ---------------------------------------------------------------------------

def test_eviction_policy_registry():
    assert {"youngest", "lru", "private-blocks"} <= set(EVICTION_POLICIES)
    cfg = _cfg()
    with pytest.raises(AssertionError, match="unknown eviction policy"):
        ServingEngine(None, cfg, BF16_ROLLOUT, eviction="nope")


def test_decision_cost_accounts_prefill_decode_and_swap(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=32, eos_id=None)
    eng.submit(_prompt(0, 8), max_new=4, rid=0)
    d = eng.step()                     # admit + one-shot prefill + decode
    assert d.prefill_tokens == eng.prompt_pad
    assert d.decode_slots == [0]
    assert d.cost_tokens == eng.prompt_pad + 1
    d = eng.step()                     # pure decode
    assert d.prefill_tokens == 0 and d.cost_tokens == 1


@pytest.mark.parametrize("policy", ["youngest", "lru", "private-blocks"])
def test_policies_complete_bit_exact_under_pressure(setup, policy):
    """Every registered policy serves an over-committed trace to
    completion with the uncontended tokens (victim choice is a
    performance decision, never a correctness one)."""
    cfg, params = setup
    prompts = [_prompt(s, int(5 + s % 8)) for s in range(6)]
    per = kv_bytes_per_token(cfg, BF16_ROLLOUT)

    def run(budget_tokens, pol):
        eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=4,
                            max_seq_len=32, admission="ondemand",
                            kv_budget_bytes=per * budget_tokens,
                            eviction=pol)
        for i, p in enumerate(prompts):
            eng.submit(p, max_new=8, rid=i)
        return eng, eng.run(max_steps=500)

    _, ref = run(400, policy)
    assert ref.preemptions == 0
    eng, rep = run(40, policy)
    assert rep.preemptions >= 1
    assert len(rep.completed) == 6
    assert {r.rid: list(r.generated) for r in rep.completed} == \
        {r.rid: list(r.generated) for r in ref.completed}
    assert eng.block_mgr.blocks_in_use == 0


def test_cow_eviction_mid_loop_skips_evicted_slot(setup):
    """Planning CoW for one slot may have to evict ANOTHER decode-ready
    slot (no free block for the copy); the CoW loop must skip the
    now-empty slot instead of crashing on it (regression: the old
    engine's `req is None` guard was lost in the scheduler split)."""
    cfg, params = setup
    from repro.serving import Request
    prompt = np.array([tasks.BOS, 5, 6, 7, 8, 9], np.int32)
    per = kv_bytes_per_token(cfg, BF16_ROLLOUT)
    # pool of exactly 2 blocks: rid 0 owns both, the fork shares both, so
    # the first divergent decode needs a CoW copy and there is NO free
    # block — the only way out is evicting the other decode-ready slot
    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=2,
                        max_seq_len=8, admission="ondemand",
                        kv_budget_bytes=per * 8)
    eng.submit(prompt, max_new=2, rid=0)
    eng._try_admit()
    req_b = Request(rid=1, prompt=prompt, max_new=2,
                    prefilled=len(prompt), cached_tokens=len(prompt))
    eng.block_mgr.fork(0, 1)
    slot = eng._free_slot()
    eng._set_table_row(slot, eng.block_mgr.blocks_of(1))
    eng.cache["lengths"] = eng.cache["lengths"].at[slot].set(len(prompt))
    eng.pending_tok[slot] = eng.pending_tok[0]
    req_b.generated = [int(eng.pending_tok[0])]
    eng.slot_req[slot] = req_b
    assert eng.block_mgr.num_free_blocks == 0
    rep = eng.run(max_steps=60)                   # must not raise
    assert rep.preemptions >= 1
    assert len(rep.completed) == 2
    got = {r.rid: list(r.generated) for r in rep.completed}
    assert got[0] == got[1]                       # same prompt, greedy


def test_revived_blocks_count_against_admission_throttle(setup):
    """Evictor-cache revivals are real allocations: a prompt whose prefix
    blocks sit in the evictor cache must spend the `StepBudget.new_blocks`
    admission throttle on them like fresh blocks (regression: `revive`
    was omitted from the budget check AND the running `fresh_blocks`
    count, so cache-warm admissions bypassed the throttle entirely)."""
    cfg, params = setup
    from repro.serving.scheduler import Admit
    prompt = _prompt(5, 8)                       # 2 full blocks of 4
    eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=4,
                        max_seq_len=32, eos_id=None,
                        step_budget=StepBudget(new_blocks=4))
    eng.submit(prompt, max_new=8, rid=0)         # reserve = 4 blocks
    rep = eng.run(max_steps=60)
    assert len(rep.completed) == 1
    assert eng.block_mgr.blocks_in_use == 0
    # rid 0's two full prompt blocks now sit in the evictor cache; the
    # next same-prompt admission revives them (2) + allocates fresh (2),
    # spending the whole 4-block budget — the second admission must wait
    eng.submit(prompt, max_new=8, rid=1)
    eng.submit(prompt, max_new=8, rid=2)
    d = eng.scheduler.step(eng)
    admits = [a for a in d.actions if isinstance(a, Admit)]
    assert len(admits) == 1, \
        "revived blocks must spend the admission block budget"
    eng.execute(d)
    eng.run(max_steps=120)
    assert {r.rid for r in eng.done} == {0, 1, 2}


# ---------------------------------------------------------------------------
# paged Pallas kernel on the serving decode path (interpret-mode parity)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("precision", [BF16_ROLLOUT, FP8_KV_ONLY_ROLLOUT],
                         ids=["bf16", "fp8"])
def test_decode_step_paged_kernel_parity(setup, precision):
    """decode_step(use_kernel=True) routes attention through the Pallas
    fp8_paged_decode_attention kernel (interpret mode on CPU) and must
    agree with the jnp table-gather path."""
    cfg, params = setup
    roll, _ = sync_policy_weights(params, precision)
    prompts = jnp.array([[1, 5, 6, 7, 8, 0], [1, 9, 10, 11, 0, 0]],
                        jnp.int32)
    lens = jnp.array([5, 4])
    cache = init_cache(cfg, 2, 16, precision, page_size=4)
    _, cache = prefill(roll, {"tokens": prompts, "lengths": lens},
                       cache, cfg, precision)
    tok = jnp.array([3, 4], jnp.int32)
    lg_ref, _, _ = decode_step(roll, tok, cache, cfg, precision)
    lg_ker, _, _ = decode_step(roll, tok, cache, cfg, precision,
                               use_kernel=True)
    np.testing.assert_allclose(np.asarray(lg_ker, np.float32),
                               np.asarray(lg_ref, np.float32),
                               rtol=2e-2, atol=2e-2)
    assert jnp.array_equal(jnp.argmax(lg_ker, -1), jnp.argmax(lg_ref, -1))


def test_engine_paged_kernel_decode_end_to_end(setup):
    """The engine flag serves a whole fp8 trace through the kernel.
    Completion and the (kernel-independent) prefill-sampled first token
    must match the gather path; later tokens may legitimately flip on
    near-tied logits (online-softmax vs full-softmax accumulation — the
    decode_step parity test above is the numerics gate)."""
    cfg, params = setup
    prec = FP8_KV_ONLY_ROLLOUT
    roll, _ = sync_policy_weights(params, prec)
    outs = {}
    for kern in ("gather", "paged"):
        eng = ServingEngine(roll, cfg, prec, max_slots=2, max_seq_len=32,
                            decode_kernel=kern)
        for i in range(3):
            eng.submit(_prompt(i, 7), max_new=5, rid=i)
        rep = eng.run(max_steps=100)
        assert len(rep.completed) == 3
        outs[kern] = {r.rid: list(r.generated) for r in rep.completed}
    for rid in outs["gather"]:
        assert outs["gather"][rid][0] == outs["paged"][rid][0]


# ---------------------------------------------------------------------------
# hypothesis property: random arrival/length/policy/layer-pattern traces
# ---------------------------------------------------------------------------

_ORACLE_CACHE = {}


def _oracle_tokens(pattern, cfg, params, prompt, max_new, chunk=None,
                   kernel="off"):
    """No-preemption single-request reference run (greedy decode depends
    only on the prompt, so this is the bit-exact ground truth).

    The oracle mirrors the numerics path under test: same kernel_config,
    and — when the prefill kernel is active — the same chunk width (the
    jnp chunked path is bit-exact vs one-shot, so only the kernel needs
    the chunking mirrored).  Scheduling pressure must never change
    tokens *given the same mechanism*."""
    chunk_eff = chunk if KernelConfig.parse(kernel).prefill else None
    key = (pattern, prompt.tobytes(), max_new, chunk_eff, kernel)
    if key not in _ORACLE_CACHE:
        eng = ServingEngine(params, cfg, BF16_ROLLOUT, max_slots=1,
                            max_seq_len=32, prefill_chunk=chunk_eff,
                            kernel_config=kernel)
        eng.submit(prompt, max_new=max_new, rid=0)
        rep = eng.run(max_steps=200)
        assert len(rep.completed) == 1
        _ORACLE_CACHE[key] = list(rep.completed[0].generated)
    return _ORACLE_CACHE[key]


@pytest.fixture(scope="module")
def zoo(setup):
    """Layer patterns the trace property draws from: pure attention and a
    jamba-style attn+ssm hybrid (whose per-slot recurrent state must also
    survive random preemption)."""
    from repro.configs import tiny_hybrid_serving_config
    hyb = tiny_hybrid_serving_config()
    return {"attn": setup,
            "hybrid": (hyb, init_params(hyb, jax.random.key(0)))}


def test_scheduler_invariants_random_traces(zoo):
    hyp = pytest.importorskip("hypothesis")
    st = hyp.strategies
    canonical = [_prompt(s, 4 + 2 * s) for s in range(4)]   # lens 4..10

    @hyp.settings(deadline=None, max_examples=8)
    @hyp.given(
        reqs=st.lists(
            st.tuples(st.integers(0, 3),      # canonical prompt index
                      st.integers(2, 5),      # max_new
                      st.integers(0, 5)),     # arrival step
            min_size=1, max_size=5),
        policy=st.sampled_from(sorted(EVICTION_POLICIES)),
        admission=st.sampled_from(["reserve", "ondemand"]),
        chunk=st.sampled_from([None, 3]),
        budget_blocks=st.integers(5, 10),
        pattern=st.sampled_from(["attn", "hybrid"]),
        kernel=st.sampled_from(["off", "decode", "all"]),
        spec_on=st.booleans(),
    )
    def run(reqs, policy, admission, chunk, budget_blocks, pattern, kernel,
            spec_on):
        cfg, params = zoo[pattern]
        per = kv_bytes_per_token(cfg, BF16_ROLLOUT)
        # KV pressure drives the preemptions; the per-slot recurrent
        # state (hybrid) always fits so admission cannot deadlock
        budget = per * 4 * budget_blocks + \
            3 * request_state_bytes(cfg, BF16_ROLLOUT)
        # speculation is opportunistic and must compose with everything
        # drawn above without changing a single greedy token (attention-
        # only models only: SSM state cannot be rewound)
        spec = SpecConfig(num_draft_tokens=3) \
            if spec_on and pattern == "attn" else None
        eng = ServingEngine(
            params, cfg, BF16_ROLLOUT, max_slots=3, max_seq_len=32,
            kv_budget_bytes=budget, admission=admission,
            eviction=policy, prefill_chunk=chunk, kernel_config=kernel,
            spec=spec)
        submitted = {}
        by_arrival = sorted(enumerate(reqs), key=lambda kv: kv[1][2])
        idx = 0
        for tick in range(400):
            while idx < len(by_arrival) and \
                    by_arrival[idx][1][2] <= tick:
                rid, (pi, max_new, _) = by_arrival[idx]
                eng.submit(canonical[pi], max_new=max_new, rid=rid)
                submitted[rid] = (pi, max_new)
                idx += 1
            decision = eng.step()
            # invariant: the block budget is NEVER exceeded after a step
            assert eng.block_mgr.blocks_in_use <= eng._effective_blocks
            # invariant: no request lost or duplicated across the three
            # populations (queued / running / done)
            queued = [r.rid for r in eng.queue]
            running = [r.rid for r in eng.slot_req if r is not None]
            done = [r.rid for r in eng.done]
            everywhere = queued + running + done
            assert sorted(everywhere) == sorted(set(everywhere))
            assert set(everywhere) == set(submitted)
            if idx == len(by_arrival) and decision.is_empty:
                break
        # every request completes with the no-preemption oracle's tokens
        assert len(eng.done) == len(submitted)
        for r in eng.done:
            pi, max_new = submitted[r.rid]
            assert list(r.generated) == _oracle_tokens(
                pattern, cfg, params, canonical[pi], max_new,
                chunk=chunk, kernel=kernel), \
                f"rid {r.rid} diverged (policy={policy}, chunk={chunk}, " \
                f"pattern={pattern}, kernel={kernel})"
        assert eng.block_mgr.blocks_in_use == 0

    run()
